//! Coordinator metrics: request counts, latency percentiles, effective
//! bandwidth, the operator's decode-cache hit/miss counters, and — in the
//! sharded tier — lock-free per-shard counters (queue depth, inflight,
//! backpressure, shard-local cache hit rate) with a
//! [`crate::store::Residency`]-style one-line summary for the serve log.

use crate::util::{fmt_bytes, stats};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Accumulated metrics (thread safe).
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
    /// Per-shard counters of the scatter/gather tier; empty when unsharded.
    shards: Vec<Arc<ShardCounters>>,
    /// Front-door admission rejections ([`super::ServeError::Rejected`]).
    rejected: AtomicU64,
}

#[derive(Default)]
struct Inner {
    requests: usize,
    batches: usize,
    batch_sizes: Vec<f64>,
    latencies: Vec<f64>,
    mvm_seconds: f64,
    bytes_touched: f64,
    // latest cumulative hot-cache counters polled from the operator
    // (absolutes, not deltas — the cache owns the running totals)
    cache_hits: u64,
    cache_misses: u64,
}

/// Immutable snapshot for reporting.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: usize,
    pub batches: usize,
    pub avg_batch: f64,
    pub p50_latency: f64,
    pub p99_latency: f64,
    pub mvm_seconds: f64,
    pub effective_gbs: f64,
    /// Cumulative decode-once hot-cache hits (0 when no cache is active).
    pub cache_hits: u64,
    /// Cumulative decode-once hot-cache misses (0 when no cache is active).
    pub cache_misses: u64,
    /// Cumulative prefetch extents issued process-wide
    /// ([`crate::store::prefetch::counters`]); 0 when prefetch is off.
    pub prefetch_issued: u64,
    /// Cumulative duplicate prefetch extents dropped before issue.
    pub prefetch_deduped: u64,
}

/// Lock-free counters for one shard worker, shared between the dispatcher
/// (enqueue/backpressure), the worker (start/finish, cache polls) and
/// reporting threads.
#[derive(Default)]
pub struct ShardCounters {
    queued: AtomicUsize,
    inflight: AtomicUsize,
    backpressure: AtomicU64,
    jobs: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    // network counters of the remote tier's couriers; all zero in-process
    net_tx: AtomicU64,
    net_rx: AtomicU64,
    round_trips: AtomicU64,
    reconnects: AtomicU64,
    net_timeouts: AtomicU64,
}

impl ShardCounters {
    /// A job entered the shard's bounded queue.
    pub fn enqueue(&self) {
        self.queued.fetch_add(1, Ordering::Relaxed);
    }

    /// The worker dequeued a job and started computing. Inflight is bumped
    /// before the queue is drained so a concurrent snapshot never undercounts
    /// `queued + inflight`, and the queue decrement saturates at zero so a
    /// `start` racing ahead of its `enqueue` cannot wrap the counter.
    pub fn start(&self) {
        self.inflight.fetch_add(1, Ordering::Relaxed);
        let _ = self
            .queued
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |q| Some(q.saturating_sub(1)));
    }

    /// The worker finished a job (panicked ones included).
    pub fn finish(&self) {
        self.inflight.fetch_sub(1, Ordering::Relaxed);
        self.jobs.fetch_add(1, Ordering::Relaxed);
    }

    /// The shard's bounded queue was full when a job was offered — the
    /// dispatcher had to block (backpressure event, not dropped work).
    pub fn backpressure(&self) {
        self.backpressure.fetch_add(1, Ordering::Relaxed);
    }

    /// Latest cumulative shard-local hot-cache counters (absolutes).
    pub fn record_cache(&self, hits: u64, misses: u64) {
        self.cache_hits.store(hits, Ordering::Relaxed);
        self.cache_misses.store(misses, Ordering::Relaxed);
    }

    /// Bytes written to the shard's worker socket (jobs, heartbeats).
    pub fn add_tx(&self, bytes: u64) {
        self.net_tx.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Bytes read from the shard's worker socket (results, pongs).
    pub fn add_rx(&self, bytes: u64) {
        self.net_rx.fetch_add(bytes, Ordering::Relaxed);
    }

    /// One job/result round trip completed over the socket.
    pub fn round_trip(&self) {
        self.round_trips.fetch_add(1, Ordering::Relaxed);
    }

    /// One reconnect attempt to the shard's worker (every attempt after the
    /// courier's very first connect counts, successful or not).
    pub fn reconnect(&self) {
        self.reconnects.fetch_add(1, Ordering::Relaxed);
    }

    /// One socket read/write deadline expired ([`super::RemoteConfig`]).
    pub fn net_timeout(&self) {
        self.net_timeouts.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> ShardSnapshot {
        ShardSnapshot {
            queued: self.queued.load(Ordering::Relaxed),
            inflight: self.inflight.load(Ordering::Relaxed),
            backpressure: self.backpressure.load(Ordering::Relaxed),
            jobs: self.jobs.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            net_tx: self.net_tx.load(Ordering::Relaxed),
            net_rx: self.net_rx.load(Ordering::Relaxed),
            round_trips: self.round_trips.load(Ordering::Relaxed),
            reconnects: self.reconnects.load(Ordering::Relaxed),
            net_timeouts: self.net_timeouts.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time view of one shard's counters.
#[derive(Clone, Debug, Default)]
pub struct ShardSnapshot {
    pub queued: usize,
    pub inflight: usize,
    pub backpressure: u64,
    pub jobs: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Bytes shipped to the shard's remote worker (0 in-process).
    pub net_tx: u64,
    /// Bytes received from the shard's remote worker (0 in-process).
    pub net_rx: u64,
    /// Completed job/result socket round trips (0 in-process).
    pub round_trips: u64,
    /// Reconnect attempts after the courier's first connect (0 in-process).
    pub reconnects: u64,
    /// Expired socket deadlines (0 in-process).
    pub net_timeouts: u64,
}

impl ShardSnapshot {
    /// Shard-local hot-cache hit rate in [0, 1]; 0 when nothing was cached.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Metrics for a scatter/gather tier of `count` shards: one
    /// [`ShardCounters`] per shard plus the shared aggregates.
    pub fn with_shards(count: usize) -> Metrics {
        Metrics {
            inner: Mutex::new(Inner::default()),
            shards: (0..count).map(|_| Arc::new(ShardCounters::default())).collect(),
            rejected: AtomicU64::new(0),
        }
    }

    /// Per-shard counters (empty when the server runs unsharded).
    pub fn shard_counters(&self) -> &[Arc<ShardCounters>] {
        &self.shards
    }

    /// Count one front-door admission rejection.
    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Total front-door admission rejections so far.
    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    /// `Residency`-style one-line shard summary for the serve log, e.g.
    /// `shards: 3 | jobs 64/64/64 | queue 0/1/0 | inflight 1 | rejected 0 |
    /// backpressure 2 | cache hit 93%/91%/95%`. `None` when unsharded.
    pub fn shard_summary(&self) -> Option<String> {
        if self.shards.is_empty() {
            return None;
        }
        let snaps: Vec<ShardSnapshot> = self.shards.iter().map(|s| s.snapshot()).collect();
        let join = |f: &dyn Fn(&ShardSnapshot) -> String| -> String {
            snaps.iter().map(|s| f(s)).collect::<Vec<_>>().join("/")
        };
        Some(format!(
            "shards: {} | jobs {} | queue {} | inflight {} | rejected {} | backpressure {} | cache hit {}",
            snaps.len(),
            join(&|s| s.jobs.to_string()),
            join(&|s| s.queued.to_string()),
            snaps.iter().map(|s| s.inflight).sum::<usize>(),
            self.rejected(),
            snaps.iter().map(|s| s.backpressure).sum::<u64>(),
            join(&|s| format!("{:.0}%", 100.0 * s.cache_hit_rate())),
        ))
    }

    /// One-line network summary of the remote fleet for the serve log, e.g.
    /// `net: tx 6.1 MiB | rx 3.2 MiB | round-trips 32/32 | reconnects 1 |
    /// timeouts 0` (round trips per shard, byte/event totals summed).
    /// `None` when unsharded or when no courier ever touched a socket
    /// (in-process sharded serving).
    pub fn net_summary(&self) -> Option<String> {
        if self.shards.is_empty() {
            return None;
        }
        let snaps: Vec<ShardSnapshot> = self.shards.iter().map(|s| s.snapshot()).collect();
        let sum = |f: &dyn Fn(&ShardSnapshot) -> u64| -> u64 { snaps.iter().map(f).sum() };
        let touched = sum(&|s| s.net_tx + s.net_rx + s.round_trips + s.reconnects + s.net_timeouts);
        if touched == 0 {
            return None;
        }
        Some(format!(
            "net: tx {} | rx {} | round-trips {} | reconnects {} | timeouts {}",
            fmt_bytes(sum(&|s| s.net_tx) as usize),
            fmt_bytes(sum(&|s| s.net_rx) as usize),
            snaps.iter().map(|s| s.round_trips.to_string()).collect::<Vec<_>>().join("/"),
            sum(&|s| s.reconnects),
            sum(&|s| s.net_timeouts),
        ))
    }

    pub fn record_batch(&self, batch_size: usize, mvm_seconds: f64, bytes: usize, latencies: &[f64]) {
        let mut g = self.inner.lock().unwrap();
        g.requests += batch_size;
        g.batches += 1;
        g.batch_sizes.push(batch_size as f64);
        g.latencies.extend_from_slice(latencies);
        g.mvm_seconds += mvm_seconds;
        g.bytes_touched += bytes as f64;
    }

    /// Store the operator's cumulative hot-cache counters (polled after each
    /// batch; the values are running totals, so the latest poll wins).
    pub fn record_cache(&self, hits: u64, misses: u64) {
        let mut g = self.inner.lock().unwrap();
        g.cache_hits = hits;
        g.cache_misses = misses;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let (prefetch_issued, prefetch_deduped) = crate::store::prefetch::counters();
        let g = self.inner.lock().unwrap();
        MetricsSnapshot {
            requests: g.requests,
            batches: g.batches,
            avg_batch: stats::mean(&g.batch_sizes),
            p50_latency: stats::percentile(&g.latencies, 50.0),
            p99_latency: stats::percentile(&g.latencies, 99.0),
            mvm_seconds: g.mvm_seconds,
            effective_gbs: if g.mvm_seconds > 0.0 { g.bytes_touched / g.mvm_seconds / 1e9 } else { 0.0 },
            cache_hits: g.cache_hits,
            cache_misses: g.cache_misses,
            prefetch_issued,
            prefetch_deduped,
        }
    }
}

impl MetricsSnapshot {
    /// Hot-cache hit rate in [0, 1]; 0 when nothing was cached.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// One-line prefetch summary for the serve log, e.g.
    /// `prefetch: 128 issued | 17 deduped`. `None` when no extent was ever
    /// offered to the prefetcher (prefetch disabled or fully in-core run).
    pub fn prefetch_summary(&self) -> Option<String> {
        if self.prefetch_issued == 0 && self.prefetch_deduped == 0 {
            return None;
        }
        Some(format!(
            "prefetch: {} issued | {} deduped",
            self.prefetch_issued, self.prefetch_deduped
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::new();
        m.record_batch(4, 0.1, 1_000_000_000, &[0.01, 0.02, 0.03, 0.04]);
        m.record_batch(2, 0.1, 1_000_000_000, &[0.05, 0.06]);
        let s = m.snapshot();
        assert_eq!(s.requests, 6);
        assert_eq!(s.batches, 2);
        assert!((s.avg_batch - 3.0).abs() < 1e-12);
        assert!((s.effective_gbs - 10.0).abs() < 1e-9);
        assert!(s.p99_latency >= s.p50_latency);
        assert_eq!(s.cache_hits, 0);
        assert_eq!(s.cache_misses, 0);
        assert_eq!(s.cache_hit_rate(), 0.0);
    }

    #[test]
    fn cache_counters_are_absolutes() {
        let m = Metrics::new();
        m.record_cache(3, 1);
        m.record_cache(30, 10); // later poll supersedes, not accumulates
        let s = m.snapshot();
        assert_eq!(s.cache_hits, 30);
        assert_eq!(s.cache_misses, 10);
        assert!((s.cache_hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn shard_counters_lifecycle() {
        let m = Metrics::with_shards(2);
        assert_eq!(m.shard_counters().len(), 2);
        let sc = &m.shard_counters()[0];
        sc.enqueue();
        sc.enqueue();
        assert_eq!(sc.snapshot().queued, 2);
        sc.start();
        let s = sc.snapshot();
        assert_eq!((s.queued, s.inflight, s.jobs), (1, 1, 0));
        sc.finish();
        sc.backpressure();
        sc.record_cache(9, 1);
        let s = sc.snapshot();
        assert_eq!((s.queued, s.inflight, s.jobs, s.backpressure), (1, 0, 1, 1));
        assert!((s.cache_hit_rate() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn empty_metrics_rates_are_zero() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.avg_batch, 0.0);
        assert_eq!(s.p50_latency, 0.0);
        assert_eq!(s.p99_latency, 0.0);
        assert_eq!(s.effective_gbs, 0.0);
        assert_eq!(s.cache_hit_rate(), 0.0);
        assert_eq!(ShardSnapshot::default().cache_hit_rate(), 0.0);
    }

    #[test]
    fn start_without_enqueue_does_not_underflow() {
        let sc = ShardCounters::default();
        // A worker racing ahead of the dispatcher's enqueue must saturate at
        // zero, not wrap to usize::MAX and poison every later queue reading.
        sc.start();
        let s = sc.snapshot();
        assert_eq!((s.queued, s.inflight), (0, 1));
        sc.finish();
        sc.enqueue();
        sc.start();
        let s = sc.snapshot();
        assert_eq!((s.queued, s.inflight, s.jobs), (0, 1, 1));
    }

    #[test]
    fn racing_counters_never_undercount_work() {
        use std::sync::Arc;
        let sc = Arc::new(ShardCounters::default());
        let jobs = 64;
        let worker = {
            let sc = Arc::clone(&sc);
            std::thread::spawn(move || {
                for _ in 0..jobs {
                    sc.enqueue();
                    sc.start();
                    sc.finish();
                }
            })
        };
        // Snapshots taken mid-race may briefly double-count one job (visible
        // as both queued and inflight between `start`'s two updates — the
        // conservative direction), but must never read a wrapped queue depth
        // or more activity than one worker can produce.
        while !worker.is_finished() {
            let s = sc.snapshot();
            assert!(s.queued <= jobs as usize, "queue depth wrapped: {}", s.queued);
            assert!(s.inflight <= 1, "single worker, inflight {}", s.inflight);
            assert!(s.jobs <= jobs, "finished more jobs than ran: {}", s.jobs);
        }
        worker.join().unwrap();
        let s = sc.snapshot();
        assert_eq!((s.queued, s.inflight, s.jobs), (0, 0, jobs));
    }

    #[test]
    fn prefetch_counters_surface_in_snapshot() {
        let s = Metrics::new().snapshot();
        // The counters are process-wide absolutes; other tests may have
        // driven the prefetcher, so only shape is asserted here.
        match s.prefetch_summary() {
            None => assert_eq!((s.prefetch_issued, s.prefetch_deduped), (0, 0)),
            Some(line) => assert!(line.starts_with("prefetch: "), "unexpected summary: {line}"),
        }
    }

    #[test]
    fn net_summary_appears_only_when_couriers_ran() {
        assert!(Metrics::new().net_summary().is_none(), "unsharded: no net line");
        let m = Metrics::with_shards(2);
        assert!(m.net_summary().is_none(), "in-process sharded: no net line");
        let sc = &m.shard_counters()[0];
        sc.add_tx(2 * 1024 * 1024);
        sc.add_rx(1024);
        sc.round_trip();
        sc.reconnect();
        sc.net_timeout();
        let s = sc.snapshot();
        assert_eq!((s.net_tx, s.net_rx, s.round_trips, s.reconnects, s.net_timeouts), (2 * 1024 * 1024, 1024, 1, 1, 1));
        let line = m.net_summary().expect("courier activity summarizes");
        assert!(line.starts_with("net: tx 2.00 MiB"), "unexpected summary: {line}");
        assert!(line.contains("round-trips 1/0"), "per-shard round trips: {line}");
        assert!(line.contains("reconnects 1"), "unexpected summary: {line}");
        assert!(line.contains("timeouts 1"), "unexpected summary: {line}");
    }

    #[test]
    fn shard_summary_line() {
        let m = Metrics::new();
        assert!(m.shard_summary().is_none());
        let m = Metrics::with_shards(3);
        m.record_rejected();
        let line = m.shard_summary().expect("sharded metrics summarize");
        assert!(line.starts_with("shards: 3"), "unexpected summary: {line}");
        assert!(line.contains("rejected 1"), "unexpected summary: {line}");
        assert!(line.contains("jobs 0/0/0"), "unexpected summary: {line}");
    }
}
