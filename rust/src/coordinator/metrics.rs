//! Coordinator metrics: request counts, latency percentiles, effective
//! bandwidth, and the operator's decode-cache hit/miss counters.

use crate::util::stats;
use std::sync::Mutex;

/// Accumulated metrics (thread safe).
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    requests: usize,
    batches: usize,
    batch_sizes: Vec<f64>,
    latencies: Vec<f64>,
    mvm_seconds: f64,
    bytes_touched: f64,
    // latest cumulative hot-cache counters polled from the operator
    // (absolutes, not deltas — the cache owns the running totals)
    cache_hits: u64,
    cache_misses: u64,
}

/// Immutable snapshot for reporting.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: usize,
    pub batches: usize,
    pub avg_batch: f64,
    pub p50_latency: f64,
    pub p99_latency: f64,
    pub mvm_seconds: f64,
    pub effective_gbs: f64,
    /// Cumulative decode-once hot-cache hits (0 when no cache is active).
    pub cache_hits: u64,
    /// Cumulative decode-once hot-cache misses (0 when no cache is active).
    pub cache_misses: u64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_batch(&self, batch_size: usize, mvm_seconds: f64, bytes: usize, latencies: &[f64]) {
        let mut g = self.inner.lock().unwrap();
        g.requests += batch_size;
        g.batches += 1;
        g.batch_sizes.push(batch_size as f64);
        g.latencies.extend_from_slice(latencies);
        g.mvm_seconds += mvm_seconds;
        g.bytes_touched += bytes as f64;
    }

    /// Store the operator's cumulative hot-cache counters (polled after each
    /// batch; the values are running totals, so the latest poll wins).
    pub fn record_cache(&self, hits: u64, misses: u64) {
        let mut g = self.inner.lock().unwrap();
        g.cache_hits = hits;
        g.cache_misses = misses;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        MetricsSnapshot {
            requests: g.requests,
            batches: g.batches,
            avg_batch: stats::mean(&g.batch_sizes),
            p50_latency: stats::percentile(&g.latencies, 50.0),
            p99_latency: stats::percentile(&g.latencies, 99.0),
            mvm_seconds: g.mvm_seconds,
            effective_gbs: if g.mvm_seconds > 0.0 { g.bytes_touched / g.mvm_seconds / 1e9 } else { 0.0 },
            cache_hits: g.cache_hits,
            cache_misses: g.cache_misses,
        }
    }
}

impl MetricsSnapshot {
    /// Hot-cache hit rate in [0, 1]; 0 when nothing was cached.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::new();
        m.record_batch(4, 0.1, 1_000_000_000, &[0.01, 0.02, 0.03, 0.04]);
        m.record_batch(2, 0.1, 1_000_000_000, &[0.05, 0.06]);
        let s = m.snapshot();
        assert_eq!(s.requests, 6);
        assert_eq!(s.batches, 2);
        assert!((s.avg_batch - 3.0).abs() < 1e-12);
        assert!((s.effective_gbs - 10.0).abs() < 1e-9);
        assert!(s.p99_latency >= s.p50_latency);
        assert_eq!(s.cache_hits, 0);
        assert_eq!(s.cache_misses, 0);
        assert_eq!(s.cache_hit_rate(), 0.0);
    }

    #[test]
    fn cache_counters_are_absolutes() {
        let m = Metrics::new();
        m.record_cache(3, 1);
        m.record_cache(30, 10); // later poll supersedes, not accumulates
        let s = m.snapshot();
        assert_eq!(s.cache_hits, 30);
        assert_eq!(s.cache_misses, 10);
        assert!((s.cache_hit_rate() - 0.75).abs() < 1e-12);
    }
}
