//! Wire codec of the cross-process shard fleet: the `ShardJob` /
//! `ShardResult` channel protocol of the in-process tier, serialized.
//!
//! Every frame is length-prefixed, versioned at the handshake, and
//! checksummed exactly like the `HMPK` pack header (FNV-1a over the frame
//! payload) — a truncated, corrupted, or cross-protocol byte stream is
//! rejected as a [`WireError`], never interpreted:
//!
//! ```text
//!   offset  size   field
//!   0       4      frame length (little-endian u32; kind + body + checksum)
//!   4       1      kind (HELLO … CRASH)
//!   5       n      body (kind-specific, little-endian fields)
//!   5+n     8      FNV-1a checksum over kind + body
//! ```
//!
//! The connection handshake carries the protocol version and the operator
//! dimensions both ways ([`Frame::Hello`] / [`Frame::HelloAck`], each
//! starting with the `HMRW` magic), then the coordinator assigns the
//! worker its [`crate::plan::ShardSpec`] ([`Frame::Assign`]) so both sides
//! build the identical row partition. Jobs ship the batch's X panel as raw
//! little-endian `f64` bits — the round trip is bitwise exact, which is
//! what keeps remote serving bitwise identical to the in-process tier.
//! The panel is encoded **once per batch** ([`encode_frame`] returns the
//! full frame bytes); the couriers of every shard write the same encoded
//! buffer and retain it for replay after a worker restart.
//!
//! [`Frame::Crash`] asks the worker to simulate a crash (drop the
//! connection without replying) — the remote half of the
//! `inject_shard_fault` kill-a-worker fault hook.

use crate::la::DMatrix;
use crate::plan::ShardSpec;
use crate::store::fnv1a;
use std::io::{Read, Write};

/// Wire protocol version, exchanged in the handshake.
pub const WIRE_VERSION: u32 = 1;

/// Handshake magic, first bytes of the Hello/HelloAck bodies.
pub const WIRE_MAGIC: &[u8; 4] = b"HMRW";

/// Upper bound on a single frame (1 GiB) — a hostile length prefix is
/// rejected before any allocation.
pub const MAX_FRAME: usize = 1 << 30;

const K_HELLO: u8 = 1;
const K_HELLO_ACK: u8 = 2;
const K_ASSIGN: u8 = 3;
const K_ASSIGN_ACK: u8 = 4;
const K_JOB: u8 = 5;
const K_RESULT: u8 = 6;
const K_PING: u8 = 7;
const K_PONG: u8 = 8;
const K_CRASH: u8 = 9;

/// Why a frame could not be read.
#[derive(Debug)]
pub enum WireError {
    /// Clean EOF at a frame boundary: the peer closed the connection.
    Closed,
    /// Socket-level failure (timeouts land here as `WouldBlock`/`TimedOut`).
    Io(std::io::Error),
    /// Malformed bytes: bad length, checksum, kind, or body shape.
    Protocol(String),
}

impl WireError {
    /// True when the error is a read/write timeout (the socket stays
    /// syntactically fine but the peer went quiet past the deadline).
    pub fn is_timeout(&self) -> bool {
        matches!(self, WireError::Io(e)
            if e.kind() == std::io::ErrorKind::WouldBlock || e.kind() == std::io::ErrorKind::TimedOut)
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Closed => write!(f, "connection closed"),
            WireError::Io(e) => write!(f, "io error: {e}"),
            WireError::Protocol(m) => write!(f, "protocol error: {m}"),
        }
    }
}

impl std::error::Error for WireError {}

/// One protocol message.
#[derive(Clone, Debug)]
pub enum Frame {
    /// Coordinator → worker, first frame on every connection.
    Hello { version: u32, nrows: u64, ncols: u64 },
    /// Worker → coordinator handshake reply; each side validates the other.
    HelloAck { version: u32, nrows: u64, ncols: u64 },
    /// Coordinator → worker: the shard of the row partition to serve.
    Assign { index: u64, count: u64, rows: (u64, u64), cols: (u64, u64) },
    /// Worker → coordinator: shard plan built, ready for jobs.
    AssignAck,
    /// One batch's X panel (raw little-endian f64 bits, bitwise exact).
    Job { seq: u64, adjoint: bool, x: DMatrix },
    /// The worker's owned rows of the batch product, or its error message.
    Result { seq: u64, rows: (u64, u64), out: Result<DMatrix, String> },
    /// Heartbeat probe (sent on idle connections).
    Ping,
    /// Heartbeat reply.
    Pong,
    /// Fault injection: simulate a worker crash (drop the connection).
    Crash,
}

/// Build the Assign frame for a shard spec.
pub fn assign_frame(spec: &ShardSpec) -> Frame {
    Frame::Assign {
        index: spec.index as u64,
        count: spec.count as u64,
        rows: (spec.rows.start as u64, spec.rows.end as u64),
        cols: (spec.cols.start as u64, spec.cols.end as u64),
    }
}

/// Rebuild the shard spec an Assign frame describes. The modeled cost share
/// is not shipped — the worker's plan slices by row range, not by cost.
pub fn spec_from_assign(index: u64, count: u64, rows: (u64, u64), cols: (u64, u64)) -> Result<ShardSpec, WireError> {
    let u = |v: u64, what: &str| -> Result<usize, WireError> {
        usize::try_from(v).map_err(|_| WireError::Protocol(format!("{what} {v} does not fit in memory")))
    };
    let spec = ShardSpec {
        index: u(index, "shard index")?,
        count: u(count, "shard count")?,
        rows: u(rows.0, "row start")?..u(rows.1, "row end")?,
        cols: u(cols.0, "col start")?..u(cols.1, "col end")?,
        cost: 0.0,
    };
    if spec.rows.start > spec.rows.end || spec.cols.start > spec.cols.end || spec.index >= spec.count.max(1) {
        return Err(WireError::Protocol(format!(
            "inverted shard spec: index {index}/{count}, rows {rows:?}, cols {cols:?}"
        )));
    }
    Ok(spec)
}

fn put_matrix(out: &mut Vec<u8>, m: &DMatrix) {
    out.extend_from_slice(&(m.nrows() as u64).to_le_bytes());
    out.extend_from_slice(&(m.ncols() as u64).to_le_bytes());
    out.reserve(m.data().len() * 8);
    for v in m.data() {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn finish_frame(p: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + p.len() + 8);
    out.extend_from_slice(&((p.len() + 8) as u32).to_le_bytes());
    out.extend_from_slice(&p);
    out.extend_from_slice(&fnv1a(&p).to_le_bytes());
    out
}

/// Encode a Job frame straight from a borrowed panel — the encode-once path
/// of the couriers: one buffer per batch, shared across shards, reconnects,
/// and replays, without cloning the matrix into a [`Frame`].
pub fn encode_job(seq: u64, adjoint: bool, x: &DMatrix) -> Vec<u8> {
    let mut p = Vec::with_capacity(26 + x.data().len() * 8);
    p.push(K_JOB);
    p.extend_from_slice(&seq.to_le_bytes());
    p.push(u8::from(adjoint));
    put_matrix(&mut p, x);
    finish_frame(p)
}

/// Encode a frame into its full wire bytes (length prefix, kind, body,
/// checksum). Couriers encode each batch's Job frame once and reuse the
/// buffer across shards, reconnects and replays.
pub fn encode_frame(f: &Frame) -> Vec<u8> {
    // payload = kind byte + body
    let mut p = Vec::with_capacity(64);
    match f {
        Frame::Hello { version, nrows, ncols } | Frame::HelloAck { version, nrows, ncols } => {
            p.push(if matches!(f, Frame::Hello { .. }) { K_HELLO } else { K_HELLO_ACK });
            p.extend_from_slice(WIRE_MAGIC);
            p.extend_from_slice(&version.to_le_bytes());
            p.extend_from_slice(&nrows.to_le_bytes());
            p.extend_from_slice(&ncols.to_le_bytes());
        }
        Frame::Assign { index, count, rows, cols } => {
            p.push(K_ASSIGN);
            for v in [*index, *count, rows.0, rows.1, cols.0, cols.1] {
                p.extend_from_slice(&v.to_le_bytes());
            }
        }
        Frame::AssignAck => p.push(K_ASSIGN_ACK),
        Frame::Job { seq, adjoint, x } => {
            p.push(K_JOB);
            p.extend_from_slice(&seq.to_le_bytes());
            p.push(u8::from(*adjoint));
            put_matrix(&mut p, x);
        }
        Frame::Result { seq, rows, out } => {
            p.push(K_RESULT);
            p.extend_from_slice(&seq.to_le_bytes());
            p.extend_from_slice(&rows.0.to_le_bytes());
            p.extend_from_slice(&rows.1.to_le_bytes());
            match out {
                Ok(m) => {
                    p.push(0);
                    put_matrix(&mut p, m);
                }
                Err(msg) => {
                    p.push(1);
                    let b = msg.as_bytes();
                    p.extend_from_slice(&(b.len() as u32).to_le_bytes());
                    p.extend_from_slice(b);
                }
            }
        }
        Frame::Ping => p.push(K_PING),
        Frame::Pong => p.push(K_PONG),
        Frame::Crash => p.push(K_CRASH),
    }
    finish_frame(p)
}

/// Encode and write one frame.
pub fn write_frame(w: &mut impl Write, f: &Frame) -> std::io::Result<()> {
    w.write_all(&encode_frame(f))
}

/// Bounds-checked little-endian cursor over a frame body.
struct Cur<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], WireError> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.b.len());
        match end {
            Some(e) => {
                let s = &self.b[self.pos..e];
                self.pos = e;
                Ok(s)
            }
            None => Err(WireError::Protocol(format!("truncated body reading {what}"))),
        }
    }

    fn u8(&mut self, what: &str) -> Result<u8, WireError> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &str) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    fn matrix(&mut self) -> Result<DMatrix, WireError> {
        let nrows = usize::try_from(self.u64("matrix rows")?)
            .map_err(|_| WireError::Protocol("matrix rows do not fit in memory".into()))?;
        let ncols = usize::try_from(self.u64("matrix cols")?)
            .map_err(|_| WireError::Protocol("matrix cols do not fit in memory".into()))?;
        let n = nrows
            .checked_mul(ncols)
            .and_then(|n| n.checked_mul(8))
            .ok_or_else(|| WireError::Protocol(format!("matrix size {nrows}x{ncols} overflows")))?;
        let raw = self.take(n, "matrix data")?;
        let data = raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect();
        Ok(DMatrix::from_vec(nrows, ncols, data))
    }

    fn done(self, kind: &str) -> Result<(), WireError> {
        if self.pos == self.b.len() {
            Ok(())
        } else {
            Err(WireError::Protocol(format!("{} trailing bytes after {kind} body", self.b.len() - self.pos)))
        }
    }
}

fn decode(kind: u8, body: &[u8]) -> Result<Frame, WireError> {
    let mut c = Cur { b: body, pos: 0 };
    let f = match kind {
        K_HELLO | K_HELLO_ACK => {
            let magic = c.take(4, "magic")?;
            if magic != WIRE_MAGIC {
                return Err(WireError::Protocol("bad handshake magic (not an hmatc wire peer)".into()));
            }
            let version = c.u32("version")?;
            let nrows = c.u64("nrows")?;
            let ncols = c.u64("ncols")?;
            if kind == K_HELLO {
                Frame::Hello { version, nrows, ncols }
            } else {
                Frame::HelloAck { version, nrows, ncols }
            }
        }
        K_ASSIGN => Frame::Assign {
            index: c.u64("index")?,
            count: c.u64("count")?,
            rows: (c.u64("rows.start")?, c.u64("rows.end")?),
            cols: (c.u64("cols.start")?, c.u64("cols.end")?),
        },
        K_ASSIGN_ACK => Frame::AssignAck,
        K_JOB => {
            let seq = c.u64("seq")?;
            let adjoint = match c.u8("adjoint flag")? {
                0 => false,
                1 => true,
                other => return Err(WireError::Protocol(format!("bad adjoint flag {other}"))),
            };
            Frame::Job { seq, adjoint, x: c.matrix()? }
        }
        K_RESULT => {
            let seq = c.u64("seq")?;
            let rows = (c.u64("rows.start")?, c.u64("rows.end")?);
            let out = match c.u8("status")? {
                0 => Ok(c.matrix()?),
                1 => {
                    let len = c.u32("error length")? as usize;
                    let raw = c.take(len, "error message")?;
                    Err(String::from_utf8_lossy(raw).into_owned())
                }
                other => return Err(WireError::Protocol(format!("bad result status {other}"))),
            };
            Frame::Result { seq, rows, out }
        }
        K_PING => Frame::Ping,
        K_PONG => Frame::Pong,
        K_CRASH => Frame::Crash,
        other => return Err(WireError::Protocol(format!("unknown frame kind {other}"))),
    };
    c.done(kind_name(kind))?;
    Ok(f)
}

fn kind_name(kind: u8) -> &'static str {
    match kind {
        K_HELLO => "hello",
        K_HELLO_ACK => "hello-ack",
        K_ASSIGN => "assign",
        K_ASSIGN_ACK => "assign-ack",
        K_JOB => "job",
        K_RESULT => "result",
        K_PING => "ping",
        K_PONG => "pong",
        K_CRASH => "crash",
        _ => "unknown",
    }
}

/// Read and validate one frame. EOF exactly between frames is
/// [`WireError::Closed`]; EOF or a timeout mid-frame, a hostile length, a
/// checksum mismatch, or a malformed body is an error — never UB, never a
/// partial frame handed to the caller.
pub fn read_frame(r: &mut impl Read) -> Result<Frame, WireError> {
    let mut len4 = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match r.read(&mut len4[got..]) {
            Ok(0) => {
                return Err(if got == 0 {
                    WireError::Closed
                } else {
                    WireError::Protocol("connection closed mid frame header".into())
                })
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    let len = u32::from_le_bytes(len4) as usize;
    if !(9..=MAX_FRAME).contains(&len) {
        return Err(WireError::Protocol(format!("frame length {len} outside [9, {MAX_FRAME}]")));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            WireError::Protocol("connection closed mid frame".into())
        } else {
            WireError::Io(e)
        }
    })?;
    let (payload, check) = buf.split_at(len - 8);
    let stored = u64::from_le_bytes(check.try_into().unwrap());
    if fnv1a(payload) != stored {
        return Err(WireError::Protocol("frame checksum mismatch".into()));
    }
    decode(payload[0], &payload[1..])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn roundtrip(f: &Frame) -> Frame {
        let bytes = encode_frame(f);
        let mut r = &bytes[..];
        let back = read_frame(&mut r).expect("roundtrip decodes");
        assert!(r.is_empty(), "decoder consumed the whole frame");
        back
    }

    #[test]
    fn frames_roundtrip_bitwise() {
        let mut rng = Rng::new(99);
        let x = DMatrix::random(7, 3, &mut rng);
        match roundtrip(&Frame::Hello { version: WIRE_VERSION, nrows: 12, ncols: 34 }) {
            Frame::Hello { version, nrows, ncols } => assert_eq!((version, nrows, ncols), (WIRE_VERSION, 12, 34)),
            other => panic!("wrong frame: {other:?}"),
        }
        match roundtrip(&Frame::Assign { index: 1, count: 3, rows: (5, 9), cols: (0, 4) }) {
            Frame::Assign { index, count, rows, cols } => {
                assert_eq!((index, count, rows, cols), (1, 3, (5, 9), (0, 4)));
            }
            other => panic!("wrong frame: {other:?}"),
        }
        match roundtrip(&Frame::Job { seq: 42, adjoint: true, x: x.clone() }) {
            Frame::Job { seq, adjoint, x: back } => {
                assert_eq!((seq, adjoint), (42, true));
                assert_eq!((back.nrows(), back.ncols()), (7, 3));
                for (a, b) in back.data().iter().zip(x.data()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "f64 bits survive the wire");
                }
            }
            other => panic!("wrong frame: {other:?}"),
        }
        match roundtrip(&Frame::Result { seq: 7, rows: (3, 10), out: Err("boom".into()) }) {
            Frame::Result { seq, rows, out } => {
                assert_eq!((seq, rows), (7, (3, 10)));
                assert_eq!(out.unwrap_err(), "boom");
            }
            other => panic!("wrong frame: {other:?}"),
        }
        for f in [Frame::AssignAck, Frame::Ping, Frame::Pong, Frame::Crash] {
            let name = format!("{f:?}");
            assert_eq!(format!("{:?}", roundtrip(&f)), name);
        }
    }

    #[test]
    fn encode_job_matches_the_frame_encoder_byte_for_byte() {
        let mut rng = Rng::new(7);
        let x = DMatrix::random(5, 2, &mut rng);
        assert_eq!(encode_job(11, false, &x), encode_frame(&Frame::Job { seq: 11, adjoint: false, x: x.clone() }));
        assert_eq!(encode_job(11, true, &x), encode_frame(&Frame::Job { seq: 11, adjoint: true, x }));
    }

    #[test]
    fn clean_eof_is_closed_and_midframe_eof_is_not() {
        let mut empty: &[u8] = &[];
        assert!(matches!(read_frame(&mut empty), Err(WireError::Closed)));
        let bytes = encode_frame(&Frame::Ping);
        for cut in 1..bytes.len() {
            let mut r = &bytes[..cut];
            match read_frame(&mut r) {
                Err(WireError::Protocol(_)) => {}
                other => panic!("cut at {cut}: expected protocol error, got {other:?}"),
            }
        }
    }

    #[test]
    fn hostile_lengths_and_checksums_rejected() {
        // hostile length prefix: rejected before any allocation
        let mut r: &[u8] = &u32::MAX.to_le_bytes();
        assert!(matches!(read_frame(&mut r), Err(WireError::Protocol(_))));
        let mut r: &[u8] = &3u32.to_le_bytes();
        assert!(matches!(read_frame(&mut r), Err(WireError::Protocol(_))));
        // flipped payload byte: checksum mismatch
        let mut bytes = encode_frame(&Frame::Assign { index: 0, count: 2, rows: (0, 5), cols: (0, 5) });
        bytes[6] ^= 0xff;
        let mut r = &bytes[..];
        match read_frame(&mut r) {
            Err(WireError::Protocol(m)) => assert!(m.contains("checksum"), "{m}"),
            other => panic!("expected checksum error, got {other:?}"),
        }
        // unknown kind (checksum fixed up to isolate the kind check)
        let payload = [200u8, 1, 2, 3];
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&((payload.len() + 8) as u32).to_le_bytes());
        bytes.extend_from_slice(&payload);
        bytes.extend_from_slice(&fnv1a(&payload).to_le_bytes());
        let mut r = &bytes[..];
        match read_frame(&mut r) {
            Err(WireError::Protocol(m)) => assert!(m.contains("unknown frame kind"), "{m}"),
            other => panic!("expected kind error, got {other:?}"),
        }
        // bad handshake magic
        let mut bytes = encode_frame(&Frame::Hello { version: WIRE_VERSION, nrows: 1, ncols: 1 });
        // recompute a valid checksum over a corrupted magic so only the magic
        // check can fire
        bytes[5] = b'X';
        let n = bytes.len();
        let sum = fnv1a(&bytes[4..n - 8]);
        bytes[n - 8..].copy_from_slice(&sum.to_le_bytes());
        let mut r = &bytes[..];
        match read_frame(&mut r) {
            Err(WireError::Protocol(m)) => assert!(m.contains("magic"), "{m}"),
            other => panic!("expected magic error, got {other:?}"),
        }
    }

    #[test]
    fn hostile_matrix_dims_rejected() {
        // a Job frame claiming a u64::MAX-sized matrix must fail the
        // checked size math, not allocate or wrap
        let mut p = vec![K_JOB];
        p.extend_from_slice(&1u64.to_le_bytes());
        p.push(0);
        p.extend_from_slice(&u64::MAX.to_le_bytes());
        p.extend_from_slice(&u64::MAX.to_le_bytes());
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&((p.len() + 8) as u32).to_le_bytes());
        bytes.extend_from_slice(&p);
        bytes.extend_from_slice(&fnv1a(&p).to_le_bytes());
        let mut r = &bytes[..];
        assert!(matches!(read_frame(&mut r), Err(WireError::Protocol(_))));
    }

    #[test]
    fn trailing_bytes_rejected() {
        let p = [K_PING, 0xAB];
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&((p.len() + 8) as u32).to_le_bytes());
        bytes.extend_from_slice(&p);
        bytes.extend_from_slice(&fnv1a(&p).to_le_bytes());
        let mut r = &bytes[..];
        match read_frame(&mut r) {
            Err(WireError::Protocol(m)) => assert!(m.contains("trailing"), "{m}"),
            other => panic!("expected trailing-bytes error, got {other:?}"),
        }
    }

    #[test]
    fn shard_spec_roundtrips_through_assign() {
        let spec = ShardSpec { index: 1, count: 2, rows: 10..20, cols: 3..9, cost: 7.5 };
        let Frame::Assign { index, count, rows, cols } = assign_frame(&spec) else {
            panic!("assign_frame builds Assign");
        };
        let back = spec_from_assign(index, count, rows, cols).expect("valid spec");
        assert_eq!((back.index, back.count), (spec.index, spec.count));
        assert_eq!((back.rows, back.cols), (spec.rows, spec.cols));
        // inverted ranges and out-of-range indices are rejected
        assert!(spec_from_assign(0, 1, (5, 2), (0, 0)).is_err());
        assert!(spec_from_assign(3, 2, (0, 1), (0, 1)).is_err());
    }
}
