//! Shard worker of the scatter/gather tier.
//!
//! One worker thread owns one [`ShardPlan`] (its sliced schedules, executor,
//! arena, and optional shard-local hot cache) and loops over a **bounded**
//! job queue: the dispatcher broadcasts each batch's X panel (an
//! `Arc<DMatrix>`, shared not copied) to every shard, the worker computes
//! the owned rows of the batch product, and ships them to the gather thread
//! on its own FIFO result channel. Gathering per-shard FIFOs in fixed shard
//! order is what makes the reassembled Y bitwise deterministic — no
//! completion-order races can reorder the row copies.
//!
//! Worker panics are contained per job: the product runs under
//! `catch_unwind`, the panic message travels to the gather thread as a
//! [`ShardResult`] error (so clients get a [`super::ServeError::ShardFailed`]
//! instead of a hang), and the worker keeps serving subsequent jobs.

use super::metrics::ShardCounters;
use crate::la::DMatrix;
use crate::plan::costmodel::{Sample, TimingSink};
use crate::plan::ShardPlan;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

/// One scatter message: the assembled X panel of a batch.
pub(crate) struct ShardJob {
    /// Batch sequence number (sanity-checked by the gather thread).
    pub seq: u64,
    /// Shared X panel, `ncols × batch` in internal ordering.
    pub x: Arc<DMatrix>,
    /// Record per-chunk timings and harvest an online-calibration
    /// observation for this job (adaptive serving only).
    pub timed: bool,
    /// Fault injection: panic instead of computing this job (in-process
    /// tier) or ask the worker to drop the connection (remote tier).
    pub fail: bool,
    /// Encoded wire Job frame of this batch, shared across every remote
    /// shard's courier: the X panel is serialized **once per batch**
    /// ([`super::wire::encode_job`]), whichever courier gets there first.
    /// Unused (never initialized) by the in-process tier.
    pub wire: Arc<std::sync::OnceLock<Vec<u8>>>,
}

/// Per-chunk timing harvest of one timed shard job, folded into the online
/// calibrator by the gather thread.
pub(crate) struct ShardObservation {
    /// Per-task `(features, nrhs, seconds)` samples of this shard's slice.
    pub samples: Vec<Sample>,
    /// Modeled shard makespan under the profile active during the run
    /// (0.0 sentinel when no online profile was active yet).
    pub predicted: f64,
    /// Measured shard makespan from the recorded per-chunk timings.
    pub measured: f64,
}

/// One gather message: the shard's owned rows of the batch product (or the
/// panic message when the shard failed on this job).
pub(crate) struct ShardResult {
    pub seq: u64,
    pub rows: std::ops::Range<usize>,
    pub out: Result<DMatrix, String>,
    /// Timing harvest when the job was [`ShardJob::timed`].
    pub obs: Option<ShardObservation>,
}

/// Worker loop: runs until the job channel closes (server drop) or the
/// gather side goes away.
pub(crate) fn shard_worker(shard: Arc<ShardPlan>, jobs: Receiver<ShardJob>, results: Sender<ShardResult>, counters: Arc<ShardCounters>) {
    // Pin the worker to its home NUMA node before any allocation: the
    // shard's arena, ybuf, and hot-cache panels are then first-touched on
    // node-local memory. Best-effort — a failed pin just leaves the worker
    // unpinned (identical outputs, only placement changes).
    let topo = crate::par::Topology::get();
    if let Some(node) = shard.home_node() {
        if topo.pin_enabled() {
            if let Some(info) = topo.nodes().iter().find(|n| n.id == node) {
                crate::par::topology::pin_current_thread(&info.cpus);
            }
        }
    }
    let rows = shard.owned(false);
    // One reusable sink sized to the shard's slice; reset per timed job.
    let sink = TimingSink::new(shard.timing_slots());
    while let Ok(job) = jobs.recv() {
        counters.start();
        let timed = job.timed;
        if timed {
            sink.reset();
        }
        let res = catch_unwind(AssertUnwindSafe(|| {
            assert!(!job.fail, "injected shard fault");
            let mut out = DMatrix::zeros(rows.len(), job.x.ncols());
            if timed {
                shard.apply_multi_owned_timed(1.0, &job.x, None, &mut out, &sink);
            } else {
                shard.apply_multi_owned(false, 1.0, &job.x, None, &mut out);
            }
            out
        }));
        counters.finish();
        if let Some((hits, misses)) = shard.cache_counters() {
            counters.record_cache(hits, misses);
        }
        let obs = match (&res, timed) {
            (Ok(out), true) => {
                let mut samples = Vec::new();
                let (predicted, measured) = shard.observe_multi(&sink, out.ncols(), &mut samples);
                Some(ShardObservation { samples, predicted, measured })
            }
            _ => None,
        };
        let out = res.map_err(|p| panic_message(p.as_ref()));
        if results.send(ShardResult { seq: job.seq, rows: rows.clone(), out, obs }).is_err() {
            return;
        }
    }
}

/// Best-effort extraction of a panic payload's message (shared with the
/// remote worker's `catch_unwind` containment).
pub(crate) fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "shard worker panicked".to_string()
    }
}
