//! MVM request coordinator (L3): queue → dynamic batcher → worker loop.
//!
//! The paper motivates MVM as the kernel of iterative solvers; in a serving
//! setting many independent right-hand sides arrive concurrently. The
//! coordinator batches them (up to `max_batch`, with a short linger window)
//! and executes one *multi-RHS* traversal per batch — amortizing every load
//! of (compressed) matrix data over the whole batch, exactly the
//! bandwidth-oriented optimization the paper targets.

mod metrics;
mod server;

pub use metrics::{Metrics, MetricsSnapshot};
pub use server::{BatchPolicy, MvmServer, Request, Response};
