//! MVM request coordinator (L3): queue → dynamic batcher → worker loop.
//!
//! The paper motivates MVM as the kernel of iterative solvers; in a serving
//! setting many independent right-hand sides arrive concurrently. The
//! coordinator batches them (up to `max_batch`, with a short linger window)
//! and executes one *multi-RHS* traversal per batch — amortizing every load
//! of (compressed) matrix data over the whole batch, exactly the
//! bandwidth-oriented optimization the paper targets.
//!
//! With `--shards N` ([`MvmServer::start_sharded`]) the single worker is
//! replaced by a scatter/gather tier over a row partition of the operator:
//! a dispatcher broadcasts each batch's X panel to per-shard workers over
//! bounded queues, a gather thread reassembles the disjoint owned rows in
//! fixed shard order (bitwise identical to the unsharded plan), and
//! admission control fails fast ([`ServeError::Rejected`]) once the pending
//! backlog hits `queue_limit`.

mod metrics;
mod server;
mod shard;

pub use metrics::{Metrics, MetricsSnapshot, ShardCounters, ShardSnapshot};
pub use server::{BatchPolicy, MvmServer, Request, Response, ServeError, ServeResult};
