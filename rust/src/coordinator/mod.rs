//! MVM request coordinator (L3): queue → dynamic batcher → worker loop.
//!
//! The paper motivates MVM as the kernel of iterative solvers; in a serving
//! setting many independent right-hand sides arrive concurrently. The
//! coordinator batches them (up to `max_batch`, with a short linger window)
//! and executes one *multi-RHS* traversal per batch — amortizing every load
//! of (compressed) matrix data over the whole batch, exactly the
//! bandwidth-oriented optimization the paper targets.
//!
//! With `--shards N` ([`MvmServer::start_sharded`]) the single worker is
//! replaced by a scatter/gather tier over a row partition of the operator:
//! a dispatcher broadcasts each batch's X panel to per-shard workers over
//! bounded queues, a gather thread reassembles the disjoint owned rows in
//! fixed shard order (bitwise identical to the unsharded plan), and
//! admission control fails fast ([`ServeError::Rejected`]) once the pending
//! backlog hits `queue_limit`.
//!
//! With `HMATC_ONLINE` ([`MvmServer::start_adaptive`] /
//! [`MvmServer::start_sharded_adaptive`]) the fixed batcher becomes a
//! continuous per-class batcher with deadline-packed panel widths, every
//! served batch is timed per chunk, and an [`OnlineCalibrator`] folds the
//! samples into the live cost model — re-balancing the packings whenever
//! predicted and measured makespans drift apart, without changing a single
//! served bit.
//!
//! With `--remote addr,addr,…` ([`MvmServer::start_remote`]) the shard
//! workers move out of the process entirely: courier threads carry the
//! scatter/gather messages over TCP ([`wire`]) to `hmatc shard-worker`
//! processes, with heartbeats, capped-backoff reconnects, and in-flight
//! replay ([`remote`]) — still bitwise identical to in-process serving.

mod adaptive;
mod metrics;
mod remote;
mod server;
mod shard;
pub mod wire;

pub use adaptive::{OnlineCalibrator, OnlineConfig, OnlineStatus};
pub use metrics::{Metrics, MetricsSnapshot, ShardCounters, ShardSnapshot};
pub use remote::{bind_listener, bind_listener_retry, serve_worker, RemoteConfig, RemoteShardClient};
pub use server::{BatchPolicy, MvmServer, Payload, Request, Response, ServeError, ServeResult};
