//! The MVM server: request queue, dynamic batcher, and either a synchronous
//! worker loop or a sharded scatter/gather tier.
//!
//! No tokio in the sandbox — the server uses std threads + channels, which is
//! adequate: the hot path is the batched MVM itself, and the coordinator adds
//! only queueing.
//!
//! The server is generic over [`HOperator`]: it serves any hierarchical
//! format (H, uniform-H, H²; compressed or not), either directly or through a
//! [`crate::plan::PlannedOperator`] for the zero-allocation schedule path.
//! Each batch runs as **one gemm-shaped multi-RHS product** (`apply_multi`),
//! so every matrix byte loaded is amortized over the whole batch. Behind a
//! `PlannedOperator::with_external_ordering`, requests may be submitted in
//! the original (external) point ordering — the permutation fold happens
//! inside the plan execution, not per client.
//!
//! The plan-execution backend is likewise the operator's concern: build the
//! `PlannedOperator` with [`crate::plan::ExecutorKind`] (`--executor` /
//! `HMATC_EXEC`) to serve on static LPT shards, the work-stealing deques, or
//! K sharded sub-pools — the server code is identical for all three, and so
//! are the served results (bitwise).
//!
//! # Sharded scatter/gather tier
//!
//! [`MvmServer::start_sharded`] replaces the single worker with a
//! dispatcher → shard workers → gather pipeline over a
//! [`crate::plan::row_partition`] of the operator:
//!
//! * the **dispatcher** batches requests exactly like the unsharded worker,
//!   then broadcasts the assembled X panel (one `Arc<DMatrix>`, shared not
//!   copied) to every shard's **bounded** job queue
//!   ([`BatchPolicy::shard_queue`]; a full queue blocks the dispatcher and
//!   counts a backpressure event) and posts a gather ticket;
//! * each **shard worker** ([`super::shard`]) computes the owned rows of the
//!   product on its own executor/arena/hot-cache;
//! * the **gather** thread reassembles Y from the per-shard FIFO result
//!   channels *in fixed shard order* (owned row ranges are disjoint, so the
//!   scatter-add degenerates to deterministic row copies — the served Y is
//!   **bitwise identical** to the unsharded plan's), records metrics, and
//!   replies. Gathering batch *k* overlaps the shards computing batch *k+1*.
//!
//! **Admission control:** [`BatchPolicy::queue_limit`] bounds the pending
//! backlog at the front door — beyond it, `submit` fails fast with
//! [`ServeError::Rejected`] instead of growing the queue. A panicking shard
//! surfaces as [`ServeError::ShardFailed`] on every request of the affected
//! batch; nothing hangs and the worker keeps serving.

use super::metrics::{Metrics, ShardCounters};
use super::shard::{shard_worker, ShardJob, ShardResult};
use crate::la::DMatrix;
use crate::plan::{row_partition, ExecutorKind, HOperator, PlannedOperator, ShardPlan};
use crate::store::HotCache;
use crate::util::Timer;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// An MVM request: a right-hand side in internal ordering.
pub struct Request {
    pub id: u64,
    pub x: Vec<f64>,
    pub submitted: Instant,
    /// Channel the response is delivered on.
    pub reply: Sender<ServeResult>,
}

/// The response: y = A x plus timing.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub y: Vec<f64>,
    /// Seconds from submission to completion.
    pub latency: f64,
    /// Batch this request was served in.
    pub batch_size: usize,
}

/// Why the server refused or failed a request.
#[derive(Clone, Debug)]
pub enum ServeError {
    /// Admission control: the pending backlog hit [`BatchPolicy::queue_limit`]
    /// and the request was rejected at the front door (fail fast, no queue).
    Rejected { pending: usize, limit: usize },
    /// A shard worker panicked while computing the request's batch.
    ShardFailed { shard: usize, message: String },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Rejected { pending, limit } => {
                write!(f, "request rejected: {pending} pending >= queue limit {limit}")
            }
            ServeError::ShardFailed { shard, message } => write!(f, "shard {shard} failed: {message}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// What a submitted request resolves to.
pub type ServeResult = Result<Response, ServeError>;

/// Dynamic batching + admission policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Maximum requests per batch.
    pub max_batch: usize,
    /// How long to wait for more requests once one is pending.
    pub linger: Duration,
    /// Reject new submissions once this many requests are pending (queued or
    /// in flight). `0` = unbounded (no admission control).
    pub queue_limit: usize,
    /// Per-shard job-queue bound (batches) of the sharded tier; a full queue
    /// applies backpressure to the dispatcher.
    pub shard_queue: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, linger: Duration::from_micros(200), queue_limit: 0, shard_queue: 2 }
    }
}

/// A synchronous MVM server over any hierarchical matrix operator.
pub struct MvmServer {
    tx: Sender<Request>,
    worker: Option<std::thread::JoinHandle<()>>,
    gather: Option<std::thread::JoinHandle<()>>,
    shard_workers: Vec<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    next_id: Mutex<u64>,
    /// Requests submitted but not yet replied to (admission control).
    pending: Arc<AtomicUsize>,
    queue_limit: usize,
    /// Test-only fault injection slot: shard index to fail on the next batch.
    fault: Arc<AtomicUsize>,
}

/// Fault-slot value meaning "no injected fault".
const NO_FAULT: usize = usize::MAX;

impl MvmServer {
    /// Start the worker loop for operator `m` (an `Arc` of any
    /// [`HOperator`] — `Arc<HMatrix>` and friends coerce directly).
    pub fn start(m: Arc<dyn HOperator>, policy: BatchPolicy) -> MvmServer {
        let (tx, rx) = channel::<Request>();
        let metrics = Arc::new(Metrics::new());
        let met = metrics.clone();
        let pending = Arc::new(AtomicUsize::new(0));
        let pend = pending.clone();
        let worker = std::thread::Builder::new()
            .name("hmatc-mvm-server".into())
            .spawn(move || worker_loop(m, policy, rx, met, pend))
            .expect("spawn server worker");
        MvmServer {
            tx,
            worker: Some(worker),
            gather: None,
            shard_workers: Vec::new(),
            metrics,
            next_id: Mutex::new(0),
            pending,
            queue_limit: policy.queue_limit,
            fault: Arc::new(AtomicUsize::new(NO_FAULT)),
        }
    }

    /// Start the scatter/gather tier: partition `op` into `shards` row
    /// shards ([`row_partition`]), give each its own worker thread (executor
    /// of `kind`, arena, and — when `HMATC_CACHE_BYTES` is set — its own
    /// hot cache), and pipeline dispatcher → workers → gather. Served
    /// results are bitwise identical to [`MvmServer::start`] over the same
    /// operator. Errors on an invalid shard count, an unpartitionable
    /// operator, or an external-ordering operator (the fold lives in the
    /// unsharded front; shard slices run internal ordering only).
    pub fn start_sharded(op: Arc<PlannedOperator>, shards: usize, kind: ExecutorKind, policy: BatchPolicy) -> Result<MvmServer, String> {
        if op.is_external_ordering() {
            return Err("sharded serving takes internal-ordering operators (drop with_external_ordering)".to_string());
        }
        let specs = row_partition(&op, shards)?;
        let plans: Vec<Arc<ShardPlan>> = specs.into_iter().map(|s| Arc::new(ShardPlan::build(&op, s, kind))).collect();
        for p in &plans {
            // shard-local decode-once cache; None leaves the parent plan's
            // shared cache active as the fallback
            p.set_hot_cache(HotCache::from_env());
        }
        let metrics = Arc::new(Metrics::with_shards(plans.len()));
        let counters: Vec<Arc<ShardCounters>> = metrics.shard_counters().to_vec();
        let pending = Arc::new(AtomicUsize::new(0));
        let fault = Arc::new(AtomicUsize::new(NO_FAULT));

        let (tx, rx) = channel::<Request>();
        let (ticket_tx, ticket_rx) = channel::<Ticket>();
        let mut job_txs = Vec::with_capacity(plans.len());
        let mut result_rxs = Vec::with_capacity(plans.len());
        let mut shard_workers = Vec::with_capacity(plans.len());
        for (i, plan) in plans.iter().enumerate() {
            let (job_tx, job_rx) = sync_channel::<ShardJob>(policy.shard_queue.max(1));
            let (res_tx, res_rx) = channel::<ShardResult>();
            let (plan, ctr) = (plan.clone(), counters[i].clone());
            let handle = std::thread::Builder::new()
                .name(format!("hmatc-shard-{i}"))
                .spawn(move || shard_worker(plan, job_rx, res_tx, ctr))
                .expect("spawn shard worker");
            job_txs.push(job_tx);
            result_rxs.push(res_rx);
            shard_workers.push(handle);
        }

        let n_in = op.ncols();
        let (disp_ctrs, disp_fault) = (counters.clone(), fault.clone());
        let worker = std::thread::Builder::new()
            .name("hmatc-mvm-dispatch".into())
            .spawn(move || dispatch_loop(n_in, policy, rx, job_txs, ticket_tx, disp_ctrs, disp_fault))
            .expect("spawn dispatcher");

        let (n_out, bytes) = (op.nrows(), op.byte_size());
        let (gather_met, gather_pend) = (metrics.clone(), pending.clone());
        let gather = std::thread::Builder::new()
            .name("hmatc-mvm-gather".into())
            .spawn(move || gather_loop(n_out, bytes, ticket_rx, result_rxs, gather_met, gather_pend))
            .expect("spawn gather");

        Ok(MvmServer {
            tx,
            worker: Some(worker),
            gather: Some(gather),
            shard_workers,
            metrics,
            next_id: Mutex::new(0),
            pending,
            queue_limit: policy.queue_limit,
            fault,
        })
    }

    /// Submit a request; returns a receiver for the outcome. With admission
    /// control active ([`BatchPolicy::queue_limit`]), an over-limit backlog
    /// resolves the receiver immediately with [`ServeError::Rejected`].
    pub fn submit(&self, x: Vec<f64>) -> Receiver<ServeResult> {
        let (reply, rx) = channel();
        if self.queue_limit > 0 {
            let p = self.pending.load(Ordering::Acquire);
            if p >= self.queue_limit {
                self.metrics.record_rejected();
                let _ = reply.send(Err(ServeError::Rejected { pending: p, limit: self.queue_limit }));
                return rx;
            }
        }
        self.pending.fetch_add(1, Ordering::AcqRel);
        let id = {
            let mut g = self.next_id.lock().unwrap();
            *g += 1;
            *g
        };
        self.tx.send(Request { id, x, submitted: Instant::now(), reply }).expect("server gone");
        rx
    }

    /// Blocking call that surfaces serve errors.
    pub fn try_call(&self, x: Vec<f64>) -> ServeResult {
        self.submit(x).recv().expect("server dropped response")
    }

    /// Blocking convenience call; panics on [`ServeError`].
    pub fn call(&self, x: Vec<f64>) -> Response {
        self.try_call(x).expect("serve error")
    }

    /// Test hook: make shard `index` panic on the next batch it receives.
    /// The affected requests must resolve to [`ServeError::ShardFailed`] —
    /// no hang — and the shard keeps serving afterwards. No-op unsharded.
    pub fn inject_shard_fault(&self, index: usize) {
        self.fault.store(index, Ordering::Release);
    }
}

impl Drop for MvmServer {
    fn drop(&mut self) {
        // close the request queue; the shutdown then cascades down the tier:
        // dispatcher exits and drops the job/ticket senders, shard workers
        // exit and drop their result senders, gather drains and exits
        let (dead_tx, _) = channel();
        let _ = std::mem::replace(&mut self.tx, dead_tx);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
        for h in self.shard_workers.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.gather.take() {
            let _ = h.join();
        }
    }
}

/// Block for the first request, then linger-fill the batch (shared by the
/// unsharded worker and the sharded dispatcher — identical batch shapes).
fn fill_batch(rx: &Receiver<Request>, policy: &BatchPolicy) -> Option<Vec<Request>> {
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    let deadline = Instant::now() + policy.linger;
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(r) => batch.push(r),
            Err(_) => break,
        }
    }
    Some(batch)
}

/// Assemble the batch's right-hand sides into one `n_in × b` panel.
fn assemble_panel(n_in: usize, batch: &[Request]) -> DMatrix {
    let mut x = DMatrix::zeros(n_in, batch.len());
    for (c, r) in batch.iter().enumerate() {
        x.col_mut(c).copy_from_slice(&r.x);
    }
    x
}

fn worker_loop(m: Arc<dyn HOperator>, policy: BatchPolicy, rx: Receiver<Request>, metrics: Arc<Metrics>, pending: Arc<AtomicUsize>) {
    let n_in = m.ncols();
    let n_out = m.nrows();
    let bytes = m.byte_size();
    while let Some(batch) = fill_batch(&rx, &policy) {
        let b = batch.len();
        let x = assemble_panel(n_in, &batch);
        let mut y = DMatrix::zeros(n_out, b);
        let t = Timer::start();
        m.apply_multi(1.0, &x, &mut y);
        let mvm_secs = t.elapsed();

        // record metrics BEFORE delivering replies: clients may snapshot the
        // metrics immediately after receiving their response
        let latencies: Vec<f64> = batch.iter().map(|r| r.submitted.elapsed().as_secs_f64()).collect();
        metrics.record_batch(b, mvm_secs, bytes, &latencies);
        if let Some((hits, misses)) = m.cache_counters() {
            metrics.record_cache(hits, misses);
        }
        for (c, r) in batch.into_iter().enumerate() {
            let latency = r.submitted.elapsed().as_secs_f64();
            let _ = r.reply.send(Ok(Response { id: r.id, y: y.col(c).to_vec(), latency, batch_size: b }));
            pending.fetch_sub(1, Ordering::AcqRel);
        }
    }
}

/// One batch in flight between the dispatcher and the gather thread.
struct Ticket {
    seq: u64,
    batch: Vec<Request>,
    timer: Timer,
}

/// Scatter side of the sharded tier: batch requests, broadcast the shared X
/// panel to every shard's bounded queue, post the gather ticket. Posting the
/// ticket first lets the gather thread overlap with shard compute.
fn dispatch_loop(
    n_in: usize,
    policy: BatchPolicy,
    rx: Receiver<Request>,
    jobs: Vec<SyncSender<ShardJob>>,
    tickets: Sender<Ticket>,
    counters: Vec<Arc<ShardCounters>>,
    fault: Arc<AtomicUsize>,
) {
    let mut seq = 0u64;
    while let Some(batch) = fill_batch(&rx, &policy) {
        let x = Arc::new(assemble_panel(n_in, &batch));
        if tickets.send(Ticket { seq, batch, timer: Timer::start() }).is_err() {
            return;
        }
        let failing = fault.swap(NO_FAULT, Ordering::AcqRel);
        for (i, js) in jobs.iter().enumerate() {
            counters[i].enqueue();
            let job = ShardJob { seq, x: x.clone(), fail: i == failing };
            match js.try_send(job) {
                Ok(()) => {}
                Err(TrySendError::Full(job)) => {
                    // bounded queue full: count the backpressure event, then
                    // block — admission control lives at the front door, so
                    // no work is dropped here
                    counters[i].backpressure();
                    if js.send(job).is_err() {
                        return;
                    }
                }
                Err(TrySendError::Disconnected(_)) => return,
            }
        }
        seq += 1;
    }
}

/// Gather side: for each ticket, collect every shard's owned rows **in fixed
/// shard order** from per-shard FIFO channels, reassemble Y (disjoint row
/// copies — bitwise deterministic), record metrics, reply. Runs one batch
/// behind the shards, overlapping gather with compute.
fn gather_loop(
    n_out: usize,
    bytes: usize,
    tickets: Receiver<Ticket>,
    results: Vec<Receiver<ShardResult>>,
    metrics: Arc<Metrics>,
    pending: Arc<AtomicUsize>,
) {
    while let Ok(t) = tickets.recv() {
        let b = t.batch.len();
        let mut y = DMatrix::zeros(n_out, b);
        let mut failure: Option<(usize, String)> = None;
        for (i, rx) in results.iter().enumerate() {
            let res = match rx.recv() {
                Ok(r) => r,
                Err(_) => {
                    if failure.is_none() {
                        failure = Some((i, "shard worker exited".to_string()));
                    }
                    continue;
                }
            };
            debug_assert_eq!(res.seq, t.seq, "per-shard FIFOs must stay in batch order");
            match res.out {
                Ok(part) => {
                    if failure.is_none() {
                        for c in 0..b {
                            y.col_mut(c)[res.rows.clone()].copy_from_slice(part.col(c));
                        }
                    }
                }
                Err(message) => {
                    if failure.is_none() {
                        failure = Some((i, message));
                    }
                }
            }
        }
        let mvm_secs = t.timer.elapsed();
        match failure {
            None => {
                let latencies: Vec<f64> = t.batch.iter().map(|r| r.submitted.elapsed().as_secs_f64()).collect();
                metrics.record_batch(b, mvm_secs, bytes, &latencies);
                let (mut hits, mut misses, mut any) = (0u64, 0u64, false);
                for sc in metrics.shard_counters() {
                    let s = sc.snapshot();
                    any |= s.cache_hits + s.cache_misses > 0;
                    hits += s.cache_hits;
                    misses += s.cache_misses;
                }
                if any {
                    metrics.record_cache(hits, misses);
                }
                for (c, r) in t.batch.into_iter().enumerate() {
                    let latency = r.submitted.elapsed().as_secs_f64();
                    let _ = r.reply.send(Ok(Response { id: r.id, y: y.col(c).to_vec(), latency, batch_size: b }));
                    pending.fetch_sub(1, Ordering::AcqRel);
                }
            }
            Some((shard, message)) => {
                for r in t.batch.into_iter() {
                    let _ = r.reply.send(Err(ServeError::ShardFailed { shard, message: message.clone() }));
                    pending.fetch_sub(1, Ordering::AcqRel);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{BlockTree, ClusterTree, StdAdmissibility};
    use crate::geometry::icosphere;
    use crate::hmatrix::HMatrix;
    use crate::kernelfn::{LaplaceSlp, MatrixGen};
    use crate::lowrank::AcaOptions;
    use crate::util::Rng;

    fn small_h() -> Arc<HMatrix> {
        let geom = icosphere(1);
        let gen = LaplaceSlp::new(&geom);
        let ct = Arc::new(ClusterTree::build(gen.points(), 8));
        let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
        Arc::new(HMatrix::build(&bt, &gen, &AcaOptions::with_eps(1e-6)))
    }

    #[test]
    fn serves_correct_results() {
        let h = small_h();
        let server = MvmServer::start(h.clone(), BatchPolicy::default());
        let mut rng = Rng::new(161);
        for _ in 0..5 {
            let x = rng.vector(h.ncols());
            let resp = server.call(x.clone());
            let mut want = vec![0.0; h.nrows()];
            crate::mvm::mvm(1.0, &h, &x, &mut want, crate::mvm::MvmAlgorithm::Seq);
            for i in 0..want.len() {
                assert!((resp.y[i] - want[i]).abs() < 1e-10);
            }
        }
        let snap = server.metrics.snapshot();
        assert_eq!(snap.requests, 5);
    }

    #[test]
    fn serves_external_ordering_requests_behind_plan() {
        // clients submit right-hand sides in the ORIGINAL point ordering; the
        // operator folds the cluster-tree permutations into the plan run
        let geom = icosphere(1);
        let gen = LaplaceSlp::new(&geom);
        let ct = Arc::new(ClusterTree::build(gen.points(), 8));
        let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
        let h = Arc::new(HMatrix::build(&bt, &gen, &AcaOptions::with_eps(1e-8)));
        let op = Arc::new(crate::plan::PlannedOperator::from_h(h.clone()).with_external_ordering());
        assert!(op.is_external_ordering());
        let server = MvmServer::start(op, BatchPolicy::default());
        let mut rng = Rng::new(163);
        for _ in 0..3 {
            let x_ext = rng.vector(h.ncols());
            let resp = server.call(x_ext.clone());
            // reference: permute manually, run internal MVM, permute back
            let xi = ct.to_internal(&x_ext);
            let mut yi = vec![0.0; h.nrows()];
            crate::mvm::mvm(1.0, &h, &xi, &mut yi, crate::mvm::MvmAlgorithm::Seq);
            let want = ct.to_external(&yi);
            for i in 0..want.len() {
                assert!((resp.y[i] - want[i]).abs() < 1e-10, "row {i}: {} vs {}", resp.y[i], want[i]);
            }
        }
    }

    #[test]
    fn serves_identically_on_every_executor_backend() {
        // same requests, one server per backend: responses must be bitwise
        // equal — the executor changes only the thread mapping
        let h = small_h();
        let mut rng = Rng::new(164);
        let xs: Vec<Vec<f64>> = (0..4).map(|_| rng.vector(h.ncols())).collect();
        let mut per_backend: Vec<Vec<Vec<f64>>> = Vec::new();
        for kind in crate::plan::ExecutorKind::all(2) {
            let op = Arc::new(crate::plan::PlannedOperator::from_h_with(h.clone(), kind));
            assert_eq!(op.executor_name(), kind.to_string());
            let server = MvmServer::start(op, BatchPolicy::default());
            per_backend.push(xs.iter().map(|x| server.call(x.clone()).y).collect());
        }
        for ys in &per_backend[1..] {
            for (a, b) in ys.iter().zip(&per_backend[0]) {
                for (va, vb) in a.iter().zip(b) {
                    assert_eq!(va.to_bits(), vb.to_bits());
                }
            }
        }
    }

    #[test]
    fn batches_concurrent_requests() {
        let h = small_h();
        let policy = BatchPolicy { max_batch: 16, linger: Duration::from_millis(20), ..BatchPolicy::default() };
        let server = Arc::new(MvmServer::start(h.clone(), policy));
        let mut rng = Rng::new(162);
        let xs: Vec<Vec<f64>> = (0..12).map(|_| rng.vector(h.ncols())).collect();
        let rxs: Vec<_> = xs.iter().map(|x| server.submit(x.clone())).collect();
        let resps: Vec<Response> = rxs.into_iter().map(|r| r.recv().unwrap().unwrap()).collect();
        // at least some requests must have shared a batch
        assert!(resps.iter().any(|r| r.batch_size > 1), "no batching happened");
        let snap = server.metrics.snapshot();
        assert_eq!(snap.requests, 12);
        assert!(snap.batches < 12);
    }

    #[test]
    fn sharded_server_matches_unsharded_bitwise() {
        let h = small_h();
        let op = Arc::new(crate::plan::PlannedOperator::from_h_with(h.clone(), crate::plan::ExecutorKind::StaticLpt));
        let mut rng = Rng::new(165);
        let xs: Vec<Vec<f64>> = (0..4).map(|_| rng.vector(h.ncols())).collect();
        let flat = MvmServer::start(op.clone(), BatchPolicy::default());
        let want: Vec<Vec<f64>> = xs.iter().map(|x| flat.call(x.clone()).y).collect();
        drop(flat);
        let sharded = MvmServer::start_sharded(op, 2, crate::plan::ExecutorKind::StaticLpt, BatchPolicy::default())
            .expect("sharded server starts");
        for (x, w) in xs.iter().zip(&want) {
            let got = sharded.call(x.clone()).y;
            for (a, b) in got.iter().zip(w) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        let line = sharded.metrics.shard_summary().expect("sharded metrics");
        assert!(line.starts_with("shards: 2"), "unexpected summary: {line}");
    }
}
