//! The MVM server: request queue, dynamic batcher, synchronous worker loop.
//!
//! No tokio in the sandbox — the server uses std threads + channels, which is
//! adequate: the hot path is the batched MVM itself, and the coordinator adds
//! only queueing.
//!
//! The server is generic over [`HOperator`]: it serves any hierarchical
//! format (H, uniform-H, H²; compressed or not), either directly or through a
//! [`crate::plan::PlannedOperator`] for the zero-allocation schedule path.
//! Each batch runs as **one gemm-shaped multi-RHS product** (`apply_multi`),
//! so every matrix byte loaded is amortized over the whole batch. Behind a
//! `PlannedOperator::with_external_ordering`, requests may be submitted in
//! the original (external) point ordering — the permutation fold happens
//! inside the plan execution, not per client.
//!
//! The plan-execution backend is likewise the operator's concern: build the
//! `PlannedOperator` with [`crate::plan::ExecutorKind`] (`--executor` /
//! `HMATC_EXEC`) to serve on static LPT shards, the work-stealing deques, or
//! K sharded sub-pools — the server code is identical for all three, and so
//! are the served results (bitwise).

use super::metrics::Metrics;
use crate::la::DMatrix;
use crate::plan::HOperator;
use crate::util::Timer;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// An MVM request: a right-hand side in internal ordering.
pub struct Request {
    pub id: u64,
    pub x: Vec<f64>,
    pub submitted: Instant,
    /// Channel the response is delivered on.
    pub reply: Sender<Response>,
}

/// The response: y = A x plus timing.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub y: Vec<f64>,
    /// Seconds from submission to completion.
    pub latency: f64,
    /// Batch this request was served in.
    pub batch_size: usize,
}

/// Dynamic batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Maximum requests per batch.
    pub max_batch: usize,
    /// How long to wait for more requests once one is pending.
    pub linger: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, linger: Duration::from_micros(200) }
    }
}

/// A synchronous MVM server over any hierarchical matrix operator.
pub struct MvmServer {
    tx: Sender<Request>,
    worker: Option<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    next_id: Mutex<u64>,
}

impl MvmServer {
    /// Start the worker loop for operator `m` (an `Arc` of any
    /// [`HOperator`] — `Arc<HMatrix>` and friends coerce directly).
    pub fn start(m: Arc<dyn HOperator>, policy: BatchPolicy) -> MvmServer {
        let (tx, rx) = channel::<Request>();
        let metrics = Arc::new(Metrics::new());
        let met = metrics.clone();
        let worker = std::thread::Builder::new()
            .name("hmatc-mvm-server".into())
            .spawn(move || worker_loop(m, policy, rx, met))
            .expect("spawn server worker");
        MvmServer { tx, worker: Some(worker), metrics, next_id: Mutex::new(0) }
    }

    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, x: Vec<f64>) -> Receiver<Response> {
        let (reply, rx) = channel();
        let id = {
            let mut g = self.next_id.lock().unwrap();
            *g += 1;
            *g
        };
        self.tx.send(Request { id, x, submitted: Instant::now(), reply }).expect("server gone");
        rx
    }

    /// Blocking convenience call.
    pub fn call(&self, x: Vec<f64>) -> Response {
        self.submit(x).recv().expect("server dropped response")
    }
}

impl Drop for MvmServer {
    fn drop(&mut self) {
        // close the queue, then join the worker
        let (dead_tx, _) = channel();
        let _ = std::mem::replace(&mut self.tx, dead_tx);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

fn worker_loop(m: Arc<dyn HOperator>, policy: BatchPolicy, rx: Receiver<Request>, metrics: Arc<Metrics>) {
    let n_in = m.ncols();
    let n_out = m.nrows();
    let bytes = m.byte_size();
    loop {
        // block for the first request
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all senders dropped
        };
        let mut batch = vec![first];
        // linger for more
        let deadline = Instant::now() + policy.linger;
        while batch.len() < policy.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(_) => break,
            }
        }

        // assemble the multivector
        let b = batch.len();
        let mut x = DMatrix::zeros(n_in, b);
        for (c, r) in batch.iter().enumerate() {
            x.col_mut(c).copy_from_slice(&r.x);
        }
        let mut y = DMatrix::zeros(n_out, b);
        let t = Timer::start();
        m.apply_multi(1.0, &x, &mut y);
        let mvm_secs = t.elapsed();

        // record metrics BEFORE delivering replies: clients may snapshot the
        // metrics immediately after receiving their response
        let latencies: Vec<f64> = batch.iter().map(|r| r.submitted.elapsed().as_secs_f64()).collect();
        metrics.record_batch(b, mvm_secs, bytes, &latencies);
        if let Some((hits, misses)) = m.cache_counters() {
            metrics.record_cache(hits, misses);
        }
        for (c, r) in batch.into_iter().enumerate() {
            let latency = r.submitted.elapsed().as_secs_f64();
            let _ = r.reply.send(Response { id: r.id, y: y.col(c).to_vec(), latency, batch_size: b });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{BlockTree, ClusterTree, StdAdmissibility};
    use crate::geometry::icosphere;
    use crate::hmatrix::HMatrix;
    use crate::kernelfn::{LaplaceSlp, MatrixGen};
    use crate::lowrank::AcaOptions;
    use crate::util::Rng;

    fn small_h() -> Arc<HMatrix> {
        let geom = icosphere(1);
        let gen = LaplaceSlp::new(&geom);
        let ct = Arc::new(ClusterTree::build(gen.points(), 8));
        let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
        Arc::new(HMatrix::build(&bt, &gen, &AcaOptions::with_eps(1e-6)))
    }

    #[test]
    fn serves_correct_results() {
        let h = small_h();
        let server = MvmServer::start(h.clone(), BatchPolicy::default());
        let mut rng = Rng::new(161);
        for _ in 0..5 {
            let x = rng.vector(h.ncols());
            let resp = server.call(x.clone());
            let mut want = vec![0.0; h.nrows()];
            crate::mvm::mvm(1.0, &h, &x, &mut want, crate::mvm::MvmAlgorithm::Seq);
            for i in 0..want.len() {
                assert!((resp.y[i] - want[i]).abs() < 1e-10);
            }
        }
        let snap = server.metrics.snapshot();
        assert_eq!(snap.requests, 5);
    }

    #[test]
    fn serves_external_ordering_requests_behind_plan() {
        // clients submit right-hand sides in the ORIGINAL point ordering; the
        // operator folds the cluster-tree permutations into the plan run
        let geom = icosphere(1);
        let gen = LaplaceSlp::new(&geom);
        let ct = Arc::new(ClusterTree::build(gen.points(), 8));
        let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
        let h = Arc::new(HMatrix::build(&bt, &gen, &AcaOptions::with_eps(1e-8)));
        let op = Arc::new(crate::plan::PlannedOperator::from_h(h.clone()).with_external_ordering());
        assert!(op.is_external_ordering());
        let server = MvmServer::start(op, BatchPolicy::default());
        let mut rng = Rng::new(163);
        for _ in 0..3 {
            let x_ext = rng.vector(h.ncols());
            let resp = server.call(x_ext.clone());
            // reference: permute manually, run internal MVM, permute back
            let xi = ct.to_internal(&x_ext);
            let mut yi = vec![0.0; h.nrows()];
            crate::mvm::mvm(1.0, &h, &xi, &mut yi, crate::mvm::MvmAlgorithm::Seq);
            let want = ct.to_external(&yi);
            for i in 0..want.len() {
                assert!((resp.y[i] - want[i]).abs() < 1e-10, "row {i}: {} vs {}", resp.y[i], want[i]);
            }
        }
    }

    #[test]
    fn serves_identically_on_every_executor_backend() {
        // same requests, one server per backend: responses must be bitwise
        // equal — the executor changes only the thread mapping
        let h = small_h();
        let mut rng = Rng::new(164);
        let xs: Vec<Vec<f64>> = (0..4).map(|_| rng.vector(h.ncols())).collect();
        let mut per_backend: Vec<Vec<Vec<f64>>> = Vec::new();
        for kind in crate::plan::ExecutorKind::all(2) {
            let op = Arc::new(crate::plan::PlannedOperator::from_h_with(h.clone(), kind));
            assert_eq!(op.executor_name(), kind.to_string());
            let server = MvmServer::start(op, BatchPolicy::default());
            per_backend.push(xs.iter().map(|x| server.call(x.clone()).y).collect());
        }
        for ys in &per_backend[1..] {
            for (a, b) in ys.iter().zip(&per_backend[0]) {
                for (va, vb) in a.iter().zip(b) {
                    assert_eq!(va.to_bits(), vb.to_bits());
                }
            }
        }
    }

    #[test]
    fn batches_concurrent_requests() {
        let h = small_h();
        let server = Arc::new(MvmServer::start(h.clone(), BatchPolicy { max_batch: 16, linger: Duration::from_millis(20) }));
        let mut rng = Rng::new(162);
        let xs: Vec<Vec<f64>> = (0..12).map(|_| rng.vector(h.ncols())).collect();
        let rxs: Vec<_> = xs.iter().map(|x| server.submit(x.clone())).collect();
        let resps: Vec<Response> = rxs.into_iter().map(|r| r.recv().unwrap()).collect();
        // at least some requests must have shared a batch
        assert!(resps.iter().any(|r| r.batch_size > 1), "no batching happened");
        let snap = server.metrics.snapshot();
        assert_eq!(snap.requests, 12);
        assert!(snap.batches < 12);
    }
}
