//! The MVM server: request queue, dynamic batcher, and either a synchronous
//! worker loop or a sharded scatter/gather tier.
//!
//! No tokio in the sandbox — the server uses std threads + channels, which is
//! adequate: the hot path is the batched MVM itself, and the coordinator adds
//! only queueing.
//!
//! The server is generic over [`HOperator`]: it serves any hierarchical
//! format (H, uniform-H, H²; compressed or not), either directly or through a
//! [`crate::plan::PlannedOperator`] for the zero-allocation schedule path.
//! Each batch runs as **one gemm-shaped multi-RHS product** (`apply_multi`),
//! so every matrix byte loaded is amortized over the whole batch. Behind a
//! `PlannedOperator::with_external_ordering`, requests may be submitted in
//! the original (external) point ordering — the permutation fold happens
//! inside the plan execution, not per client.
//!
//! The plan-execution backend is likewise the operator's concern: build the
//! `PlannedOperator` with [`crate::plan::ExecutorKind`] (`--executor` /
//! `HMATC_EXEC`) to serve on static LPT shards, the work-stealing deques, or
//! K sharded sub-pools — the server code is identical for all three, and so
//! are the served results (bitwise).
//!
//! # Sharded scatter/gather tier
//!
//! [`MvmServer::start_sharded`] replaces the single worker with a
//! dispatcher → shard workers → gather pipeline over a
//! [`crate::plan::row_partition`] of the operator:
//!
//! * the **dispatcher** batches requests exactly like the unsharded worker,
//!   then broadcasts the assembled X panel (one `Arc<DMatrix>`, shared not
//!   copied) to every shard's **bounded** job queue
//!   ([`BatchPolicy::shard_queue`]; a full queue blocks the dispatcher and
//!   counts a backpressure event) and posts a gather ticket;
//! * each **shard worker** ([`super::shard`]) computes the owned rows of the
//!   product on its own executor/arena/hot-cache;
//! * the **gather** thread reassembles Y from the per-shard FIFO result
//!   channels *in fixed shard order* (owned row ranges are disjoint, so the
//!   scatter-add degenerates to deterministic row copies — the served Y is
//!   **bitwise identical** to the unsharded plan's), records metrics, and
//!   replies. Gathering batch *k* overlaps the shards computing batch *k+1*.
//!
//! **Admission control:** [`BatchPolicy::queue_limit`] bounds the pending
//! backlog at the front door — beyond it, `submit` fails fast with
//! [`ServeError::Rejected`] instead of growing the queue. A panicking shard
//! surfaces as [`ServeError::ShardFailed`] on every request of the affected
//! batch; nothing hangs and the worker keeps serving.
//!
//! [`MvmServer::start_remote`] swaps the in-process shard workers for
//! courier threads speaking the [`super::wire`] protocol to `hmatc
//! shard-worker` processes ([`super::remote`]) — same pipeline, same
//! bitwise-identical results, plus reconnect/replay fleet robustness.
//!
//! # Adaptive serving ([`MvmServer::start_adaptive`])
//!
//! The adaptive loop replaces the fixed [`BatchPolicy`] batcher with
//! **continuous batching**: queued single-RHS and multi-RHS jobs are
//! coalesced into per-request-class panels whose width follows the live
//! cost profile's panel scaling, bounded by the oldest request's remaining
//! latency deadline ([`OnlineConfig::deadline`]). Single-column batches are
//! routed to a low-overhead static-LPT executor route, panels to the
//! operator's own backend — all executors produce bitwise-identical
//! products, so routing never changes served bits. Every served batch runs
//! timed; the harvested per-chunk samples feed the [`OnlineCalibrator`],
//! which re-fits the cost model and atomically swaps re-balanced packings
//! when the modeled makespan drifts from the measured one.

use super::adaptive::{OnlineCalibrator, OnlineConfig, OnlineStatus};
use super::metrics::{Metrics, ShardCounters};
use super::remote::{courier_loop, RemoteConfig};
use super::shard::{shard_worker, ShardJob, ShardObservation, ShardResult};
use crate::la::DMatrix;
use crate::plan::costmodel::{Sample, TimingSink};
use crate::plan::{row_partition, ExecutorKind, HOperator, PlannedOperator, ShardPlan};
use crate::store::HotCache;
use crate::util::Timer;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

/// A request's right-hand side(s) in internal ordering: one vector or a
/// multi-RHS panel. The two variants are the batching **classes** of the
/// adaptive dispatcher — singles coalesce with singles, panels with panels.
pub enum Payload {
    /// One right-hand-side vector (width 1).
    Single(Vec<f64>),
    /// A multi-RHS panel, `n × k` column-major.
    Panel(DMatrix),
}

impl Payload {
    /// Columns this request contributes to the batch product.
    pub fn width(&self) -> usize {
        match self {
            Payload::Single(_) => 1,
            Payload::Panel(p) => p.ncols(),
        }
    }

    fn is_single(&self) -> bool {
        matches!(self, Payload::Single(_))
    }
}

/// An MVM request: one or more right-hand sides in internal ordering.
pub struct Request {
    pub id: u64,
    pub payload: Payload,
    pub submitted: Instant,
    /// Channel the response is delivered on.
    pub reply: Sender<ServeResult>,
}

/// The response: y = A x plus timing. For panel requests, `y` holds the
/// `ncols` output columns concatenated column-major.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub y: Vec<f64>,
    /// Output columns in `y` (1 for single-RHS requests).
    pub ncols: usize,
    /// Seconds from submission to completion.
    pub latency: f64,
    /// Requests sharing the batch this one was served in.
    pub batch_size: usize,
}

/// Why the server refused or failed a request.
#[derive(Clone, Debug)]
pub enum ServeError {
    /// Admission control: the pending backlog hit [`BatchPolicy::queue_limit`]
    /// and the request was rejected at the front door (fail fast, no queue).
    Rejected { pending: usize, limit: usize },
    /// A shard worker panicked while computing the request's batch.
    ShardFailed { shard: usize, message: String },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Rejected { pending, limit } => {
                write!(f, "request rejected: {pending} pending >= queue limit {limit}")
            }
            ServeError::ShardFailed { shard, message } => write!(f, "shard {shard} failed: {message}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// What a submitted request resolves to.
pub type ServeResult = Result<Response, ServeError>;

/// Dynamic batching + admission policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Maximum requests per batch.
    pub max_batch: usize,
    /// How long to wait for more requests once one is pending.
    pub linger: Duration,
    /// Reject new submissions once this many requests are pending (queued or
    /// in flight). `0` = unbounded (no admission control).
    pub queue_limit: usize,
    /// Per-shard job-queue bound (batches) of the sharded tier; a full queue
    /// applies backpressure to the dispatcher.
    pub shard_queue: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, linger: Duration::from_micros(200), queue_limit: 0, shard_queue: 2 }
    }
}

/// A synchronous MVM server over any hierarchical matrix operator.
pub struct MvmServer {
    tx: Sender<Request>,
    worker: Option<std::thread::JoinHandle<()>>,
    gather: Option<std::thread::JoinHandle<()>>,
    shard_workers: Vec<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    /// Lock-free request id tick: a plain atomic so a client thread that
    /// panics mid-submit can never poison the front door for everyone else.
    next_id: AtomicU64,
    /// Requests submitted but not yet replied to (admission control).
    pending: Arc<AtomicUsize>,
    queue_limit: usize,
    /// Test-only fault injection slot: shard index to fail on the next batch.
    fault: Arc<AtomicUsize>,
    /// Online calibrator of the adaptive loop; `None` on static servers.
    calibrator: Option<Arc<OnlineCalibrator>>,
}

/// Fault-slot value meaning "no injected fault".
const NO_FAULT: usize = usize::MAX;

impl MvmServer {
    /// Start the worker loop for operator `m` (an `Arc` of any
    /// [`HOperator`] — `Arc<HMatrix>` and friends coerce directly).
    pub fn start(m: Arc<dyn HOperator>, policy: BatchPolicy) -> MvmServer {
        let (tx, rx) = channel::<Request>();
        let metrics = Arc::new(Metrics::new());
        let met = metrics.clone();
        let pending = Arc::new(AtomicUsize::new(0));
        let pend = pending.clone();
        let worker = std::thread::Builder::new()
            .name("hmatc-mvm-server".into())
            .spawn(move || worker_loop(m, policy, rx, met, pend))
            .expect("spawn server worker");
        MvmServer {
            tx,
            worker: Some(worker),
            gather: None,
            shard_workers: Vec::new(),
            metrics,
            next_id: AtomicU64::new(0),
            pending,
            queue_limit: policy.queue_limit,
            fault: Arc::new(AtomicUsize::new(NO_FAULT)),
            calibrator: None,
        }
    }

    /// Start the adaptive serving loop over a planned operator: continuous
    /// per-class batching against [`OnlineConfig::deadline`], per-class
    /// executor routing (single-column batches run a low-overhead static-LPT
    /// route, panels run `op`'s own backend), live per-chunk timing, and an
    /// [`OnlineCalibrator`] that re-fits the cost model and swaps re-balanced
    /// packings on drift. Served results are **bitwise identical** to
    /// [`MvmServer::start`] over the same operator — adaptation only moves
    /// task→shard boundaries and batch seams, never task bodies or their
    /// summation order.
    pub fn start_adaptive(op: Arc<PlannedOperator>, policy: BatchPolicy, cfg: OnlineConfig) -> MvmServer {
        let narrow = if op.executor_name() == ExecutorKind::StaticLpt.to_string() {
            op.clone()
        } else {
            Arc::new(op.rebuilt_with(ExecutorKind::StaticLpt))
        };
        let mut registered = vec![op.clone()];
        if !Arc::ptr_eq(&op, &narrow) {
            registered.push(narrow.clone());
        }
        let calibrator = Arc::new(OnlineCalibrator::new(cfg.clone(), registered));

        let (tx, rx) = channel::<Request>();
        let metrics = Arc::new(Metrics::new());
        let pending = Arc::new(AtomicUsize::new(0));
        let (met, pend, cal) = (metrics.clone(), pending.clone(), calibrator.clone());
        let routes = Routes { primary: op, narrow };
        let worker = std::thread::Builder::new()
            .name("hmatc-mvm-adaptive".into())
            .spawn(move || adaptive_worker_loop(routes, policy, cfg, cal, rx, met, pend))
            .expect("spawn adaptive worker");
        MvmServer {
            tx,
            worker: Some(worker),
            gather: None,
            shard_workers: Vec::new(),
            metrics,
            next_id: AtomicU64::new(0),
            pending,
            queue_limit: policy.queue_limit,
            fault: Arc::new(AtomicUsize::new(NO_FAULT)),
            calibrator: Some(calibrator),
        }
    }

    /// Start the scatter/gather tier: partition `op` into `shards` row
    /// shards ([`row_partition`]), give each its own worker thread (executor
    /// of `kind`, arena, and — when `HMATC_CACHE_BYTES` is set — its own
    /// hot cache), and pipeline dispatcher → workers → gather. Served
    /// results are bitwise identical to [`MvmServer::start`] over the same
    /// operator. Errors on an invalid shard count, an unpartitionable
    /// operator, or an external-ordering operator (the fold lives in the
    /// unsharded front; shard slices run internal ordering only).
    pub fn start_sharded(op: Arc<PlannedOperator>, shards: usize, kind: ExecutorKind, policy: BatchPolicy) -> Result<MvmServer, String> {
        MvmServer::start_sharded_inner(op, shards, kind, policy, None)
    }

    /// Sharded scatter/gather tier with online adaptation: the dispatcher
    /// runs the continuous per-class batcher (deadline-packed panel widths
    /// from the parent operator's live cost model) and marks every job
    /// timed; shard workers harvest per-chunk timings of their slices; the
    /// gather thread folds the per-shard observations (concatenated samples,
    /// makespan = max across shards) into the [`OnlineCalibrator`] once per
    /// batch. A packing swap re-partitions the parent schedules; shard
    /// slices re-pack lazily through the generation-keyed packing caches.
    /// Served results stay bitwise identical to the static sharded tier.
    pub fn start_sharded_adaptive(
        op: Arc<PlannedOperator>,
        shards: usize,
        kind: ExecutorKind,
        policy: BatchPolicy,
        cfg: OnlineConfig,
    ) -> Result<MvmServer, String> {
        MvmServer::start_sharded_inner(op, shards, kind, policy, Some(cfg))
    }

    fn start_sharded_inner(
        op: Arc<PlannedOperator>,
        shards: usize,
        kind: ExecutorKind,
        policy: BatchPolicy,
        online: Option<OnlineConfig>,
    ) -> Result<MvmServer, String> {
        if op.is_external_ordering() {
            return Err("sharded serving takes internal-ordering operators (drop with_external_ordering)".to_string());
        }
        let specs = row_partition(&op, shards)?;
        let plans: Vec<Arc<ShardPlan>> = specs.into_iter().map(|s| Arc::new(ShardPlan::build(&op, s, kind))).collect();
        for p in &plans {
            // shard-local decode-once cache; None leaves the parent plan's
            // shared cache active as the fallback
            p.set_hot_cache(HotCache::from_env());
        }
        let metrics = Arc::new(Metrics::with_shards(plans.len()));
        let counters: Vec<Arc<ShardCounters>> = metrics.shard_counters().to_vec();
        let pending = Arc::new(AtomicUsize::new(0));
        let fault = Arc::new(AtomicUsize::new(NO_FAULT));

        let (tx, rx) = channel::<Request>();
        let (ticket_tx, ticket_rx) = channel::<Ticket>();
        let mut job_txs = Vec::with_capacity(plans.len());
        let mut result_rxs = Vec::with_capacity(plans.len());
        let mut shard_workers = Vec::with_capacity(plans.len());
        for (i, plan) in plans.iter().enumerate() {
            let (job_tx, job_rx) = sync_channel::<ShardJob>(policy.shard_queue.max(1));
            let (res_tx, res_rx) = channel::<ShardResult>();
            let (plan, ctr) = (plan.clone(), counters[i].clone());
            let handle = std::thread::Builder::new()
                .name(format!("hmatc-shard-{i}"))
                .spawn(move || shard_worker(plan, job_rx, res_tx, ctr))
                .expect("spawn shard worker");
            job_txs.push(job_tx);
            result_rxs.push(res_rx);
            shard_workers.push(handle);
        }

        let calibrator = online
            .as_ref()
            .map(|cfg| Arc::new(OnlineCalibrator::new(cfg.clone(), vec![op.clone()])));
        let adaptive = online.map(|cfg| AdaptiveDispatch { op: op.clone(), cfg });

        let n_in = op.ncols();
        let (disp_ctrs, disp_fault) = (counters.clone(), fault.clone());
        let worker = std::thread::Builder::new()
            .name("hmatc-mvm-dispatch".into())
            .spawn(move || dispatch_loop(n_in, policy, adaptive, rx, job_txs, ticket_tx, disp_ctrs, disp_fault))
            .expect("spawn dispatcher");

        let (n_out, bytes) = (op.nrows(), op.byte_size());
        let (gather_met, gather_pend, gather_cal) = (metrics.clone(), pending.clone(), calibrator.clone());
        let gather = std::thread::Builder::new()
            .name("hmatc-mvm-gather".into())
            .spawn(move || gather_loop(n_out, bytes, ticket_rx, result_rxs, gather_met, gather_pend, gather_cal))
            .expect("spawn gather");

        Ok(MvmServer {
            tx,
            worker: Some(worker),
            gather: Some(gather),
            shard_workers,
            metrics,
            next_id: AtomicU64::new(0),
            pending,
            queue_limit: policy.queue_limit,
            fault,
            calibrator,
        })
    }

    /// Start the cross-process fleet tier: the same dispatcher → shards →
    /// gather pipeline as [`MvmServer::start_sharded`], but each shard is a
    /// **courier thread** speaking the [`super::wire`] protocol to a remote
    /// `hmatc shard-worker` process — one worker per address, shard `i` of
    /// the [`row_partition`] assigned to `addrs[i]`. The couriers encode
    /// each batch's X panel once, pipeline jobs over the sockets so writes
    /// overlap worker compute, heartbeat idle connections, and reconnect
    /// with capped backoff + in-flight replay ([`RemoteConfig`]). The
    /// gather thread cannot tell couriers from local workers: served
    /// results are **bitwise identical** to in-process sharded serving, and
    /// an unreachable worker surfaces as [`ServeError::ShardFailed`] after
    /// [`RemoteConfig::max_attempts`], never as a hang.
    pub fn start_remote(
        op: Arc<PlannedOperator>,
        addrs: &[String],
        policy: BatchPolicy,
        cfg: RemoteConfig,
    ) -> Result<MvmServer, String> {
        if op.is_external_ordering() {
            return Err("remote serving takes internal-ordering operators (drop with_external_ordering)".to_string());
        }
        if addrs.is_empty() {
            return Err("remote serving needs at least one worker address".to_string());
        }
        let specs = row_partition(&op, addrs.len())?;
        let metrics = Arc::new(Metrics::with_shards(specs.len()));
        let counters: Vec<Arc<ShardCounters>> = metrics.shard_counters().to_vec();
        let pending = Arc::new(AtomicUsize::new(0));
        let fault = Arc::new(AtomicUsize::new(NO_FAULT));
        let dims = (op.nrows() as u64, op.ncols() as u64);

        let (tx, rx) = channel::<Request>();
        let (ticket_tx, ticket_rx) = channel::<Ticket>();
        let mut job_txs = Vec::with_capacity(specs.len());
        let mut result_rxs = Vec::with_capacity(specs.len());
        let mut couriers = Vec::with_capacity(specs.len());
        for (i, spec) in specs.into_iter().enumerate() {
            let (job_tx, job_rx) = sync_channel::<ShardJob>(policy.shard_queue.max(1));
            let (res_tx, res_rx) = channel::<ShardResult>();
            let (addr, ctr, c) = (addrs[i].clone(), counters[i].clone(), cfg.clone());
            let handle = std::thread::Builder::new()
                .name(format!("hmatc-courier-{i}"))
                .spawn(move || courier_loop(addr, spec, dims, c, job_rx, res_tx, ctr))
                .expect("spawn shard courier");
            job_txs.push(job_tx);
            result_rxs.push(res_rx);
            couriers.push(handle);
        }

        let n_in = op.ncols();
        let (disp_ctrs, disp_fault) = (counters.clone(), fault.clone());
        let worker = std::thread::Builder::new()
            .name("hmatc-mvm-dispatch".into())
            .spawn(move || dispatch_loop(n_in, policy, None, rx, job_txs, ticket_tx, disp_ctrs, disp_fault))
            .expect("spawn dispatcher");

        let (n_out, bytes) = (op.nrows(), op.byte_size());
        let (gather_met, gather_pend) = (metrics.clone(), pending.clone());
        let gather = std::thread::Builder::new()
            .name("hmatc-mvm-gather".into())
            .spawn(move || gather_loop(n_out, bytes, ticket_rx, result_rxs, gather_met, gather_pend, None))
            .expect("spawn gather");

        Ok(MvmServer {
            tx,
            worker: Some(worker),
            gather: Some(gather),
            shard_workers: couriers,
            metrics,
            next_id: AtomicU64::new(0),
            pending,
            queue_limit: policy.queue_limit,
            fault,
            calibrator: None,
        })
    }

    /// Submit a single-RHS request; returns a receiver for the outcome. With
    /// admission control active ([`BatchPolicy::queue_limit`]), an over-limit
    /// backlog resolves the receiver immediately with [`ServeError::Rejected`].
    pub fn submit(&self, x: Vec<f64>) -> Receiver<ServeResult> {
        self.submit_payload(Payload::Single(x))
    }

    /// Submit a multi-RHS panel (`ncols × k`); the response's `y` holds the
    /// `k` output columns concatenated column-major (`Response::ncols = k`).
    pub fn submit_panel(&self, x: DMatrix) -> Receiver<ServeResult> {
        self.submit_payload(Payload::Panel(x))
    }

    fn submit_payload(&self, payload: Payload) -> Receiver<ServeResult> {
        let (reply, rx) = channel();
        if self.queue_limit > 0 {
            let p = self.pending.load(Ordering::Acquire);
            if p >= self.queue_limit {
                self.metrics.record_rejected();
                let _ = reply.send(Err(ServeError::Rejected { pending: p, limit: self.queue_limit }));
                return rx;
            }
        }
        self.pending.fetch_add(1, Ordering::AcqRel);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        self.tx.send(Request { id, payload, submitted: Instant::now(), reply }).expect("server gone");
        rx
    }

    /// Blocking call that surfaces serve errors.
    pub fn try_call(&self, x: Vec<f64>) -> ServeResult {
        self.submit(x).recv().expect("server dropped response")
    }

    /// Blocking convenience call; panics on [`ServeError`].
    pub fn call(&self, x: Vec<f64>) -> Response {
        self.try_call(x).expect("serve error")
    }

    /// Blocking multi-RHS panel call; panics on [`ServeError`].
    pub fn call_panel(&self, x: DMatrix) -> Response {
        self.submit_panel(x).recv().expect("server dropped response").expect("serve error")
    }

    /// Online calibrator counters of an adaptive server; `None` on static
    /// servers.
    pub fn online_status(&self) -> Option<OnlineStatus> {
        self.calibrator.as_ref().map(|c| c.status())
    }

    /// The adaptive server's calibrator (tests and the serve smoke use it to
    /// force mid-stream re-fits); `None` on static servers.
    pub fn calibrator(&self) -> Option<&Arc<OnlineCalibrator>> {
        self.calibrator.as_ref()
    }

    /// Fault-injection hook: make shard `index` fail its next batch — an
    /// injected panic on the in-process tier, a simulated worker crash
    /// (connection drop, then reconnect + replay) on the remote tier. The
    /// affected requests must resolve to [`ServeError::ShardFailed`] or be
    /// transparently replayed — no hang — and the tier keeps serving.
    /// No-op unsharded. Compiled only into tests and `--features
    /// fault-inject` builds; release servers have no kill switch.
    #[cfg(any(test, feature = "fault-inject"))]
    pub fn inject_shard_fault(&self, index: usize) {
        self.fault.store(index, Ordering::Release);
    }
}

impl Drop for MvmServer {
    fn drop(&mut self) {
        // close the request queue; the shutdown then cascades down the tier:
        // dispatcher exits and drops the job/ticket senders, shard workers
        // exit and drop their result senders, gather drains and exits
        let (dead_tx, _) = channel();
        let _ = std::mem::replace(&mut self.tx, dead_tx);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
        for h in self.shard_workers.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.gather.take() {
            let _ = h.join();
        }
    }
}

/// Block for the first request, then linger-fill the batch (shared by the
/// unsharded worker and the sharded dispatcher — identical batch shapes).
fn fill_batch(rx: &Receiver<Request>, policy: &BatchPolicy) -> Option<Vec<Request>> {
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    let deadline = Instant::now() + policy.linger;
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(r) => batch.push(r),
            Err(_) => break,
        }
    }
    Some(batch)
}

/// Continuous batcher of the adaptive loop: linger-drain the queue into the
/// carry, then coalesce the **oldest** request's class (single-RHS vs
/// multi-RHS) into one product panel whose summed width the `cap` callback
/// bounds (deadline-packed under a live cost profile). Requests of the other
/// class — and same-class overflow — stay carried, in order, for the next
/// iteration; the carry front always dictates the next batch, so neither
/// class can starve the other.
fn fill_class_batch(
    rx: &Receiver<Request>,
    carry: &mut VecDeque<Request>,
    policy: &BatchPolicy,
    cap: &dyn Fn(Duration) -> usize,
) -> Option<Vec<Request>> {
    if carry.is_empty() {
        carry.push_back(rx.recv().ok()?);
    }
    if carry.len() == 1 {
        // nothing carried over: linger for companions like the static batcher
        let deadline = Instant::now() + policy.linger;
        loop {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => carry.push_back(r),
                Err(_) => break,
            }
        }
    }
    // free companions: whatever is already sitting in the channel
    while let Ok(r) = rx.try_recv() {
        carry.push_back(r);
    }
    let width_cap = cap(carry[0].submitted.elapsed());
    let single = carry[0].payload.is_single();
    let mut batch = Vec::new();
    let mut width = 0usize;
    let mut rest = VecDeque::new();
    for r in carry.drain(..) {
        let w = r.payload.width();
        // the head request always runs, even when wider than the cap
        if r.payload.is_single() == single && (batch.is_empty() || width + w <= width_cap) {
            width += w;
            batch.push(r);
        } else {
            rest.push_back(r);
        }
    }
    *carry = rest;
    Some(batch)
}

/// Deadline-bounded coalesced panel width: with an active online profile the
/// modeled batch cost `fixed + b·per_col` (seconds) is packed against the
/// oldest queued request's remaining deadline; without a profile
/// (pre-bootstrap, static byte-unit costs) the static `max_batch` applies.
/// Always clamped to `[1, cfg.max_panel]`.
fn panel_cap(op: &PlannedOperator, cfg: &OnlineConfig, policy: &BatchPolicy, oldest_wait: Duration) -> usize {
    let cap = match op.panel_cost_model() {
        None => policy.max_batch,
        Some((fixed, per_col)) if per_col > 0.0 => {
            let remaining = cfg.deadline.saturating_sub(oldest_wait).as_secs_f64();
            ((remaining - fixed) / per_col).max(0.0).floor() as usize
        }
        Some(_) => cfg.max_panel,
    };
    cap.clamp(1, cfg.max_panel.max(1))
}

/// Assemble the batch's right-hand sides into one `n_in × w` panel, `w` the
/// summed payload width; a panel payload occupies consecutive columns.
fn assemble_panel(n_in: usize, batch: &[Request]) -> DMatrix {
    let w: usize = batch.iter().map(|r| r.payload.width()).sum();
    let mut x = DMatrix::zeros(n_in, w);
    let mut c = 0;
    for r in batch {
        match &r.payload {
            Payload::Single(v) => {
                x.col_mut(c).copy_from_slice(v);
                c += 1;
            }
            Payload::Panel(p) => {
                for k in 0..p.ncols() {
                    x.col_mut(c).copy_from_slice(p.col(k));
                    c += 1;
                }
            }
        }
    }
    x
}

/// Deliver each request its columns of the batch product (column-major
/// concatenation for panels), in submit order.
fn reply_ok(batch: Vec<Request>, y: &DMatrix, nreq: usize, pending: &AtomicUsize) {
    let mut c = 0;
    for r in batch {
        let k = r.payload.width();
        let mut out = Vec::with_capacity(y.nrows() * k);
        for j in 0..k {
            out.extend_from_slice(y.col(c + j));
        }
        c += k;
        let latency = r.submitted.elapsed().as_secs_f64();
        let _ = r.reply.send(Ok(Response { id: r.id, y: out, ncols: k, latency, batch_size: nreq }));
        pending.fetch_sub(1, Ordering::AcqRel);
    }
}

fn worker_loop(m: Arc<dyn HOperator>, policy: BatchPolicy, rx: Receiver<Request>, metrics: Arc<Metrics>, pending: Arc<AtomicUsize>) {
    let n_in = m.ncols();
    let n_out = m.nrows();
    let bytes = m.byte_size();
    while let Some(batch) = fill_batch(&rx, &policy) {
        let b = batch.len();
        let x = assemble_panel(n_in, &batch);
        let mut y = DMatrix::zeros(n_out, x.ncols());
        let t = Timer::start();
        m.apply_multi(1.0, &x, &mut y);
        let mvm_secs = t.elapsed();

        // record metrics BEFORE delivering replies: clients may snapshot the
        // metrics immediately after receiving their response
        let latencies: Vec<f64> = batch.iter().map(|r| r.submitted.elapsed().as_secs_f64()).collect();
        metrics.record_batch(b, mvm_secs, bytes, &latencies);
        if let Some((hits, misses)) = m.cache_counters() {
            metrics.record_cache(hits, misses);
        }
        reply_ok(batch, &y, b, &pending);
    }
}

/// The adaptive server's per-class executor routes. The narrow route serves
/// single-column batches on a low-overhead static-LPT schedule; panels run
/// the primary backend. Both share the matrix and hot cache, and every
/// executor yields bitwise-identical products, so routing never changes
/// served bits — only scheduling overhead.
struct Routes {
    primary: Arc<PlannedOperator>,
    narrow: Arc<PlannedOperator>,
}

impl Routes {
    fn pick(&self, width: usize) -> &Arc<PlannedOperator> {
        if width == 1 {
            &self.narrow
        } else {
            &self.primary
        }
    }
}

fn adaptive_worker_loop(
    routes: Routes,
    policy: BatchPolicy,
    cfg: OnlineConfig,
    calib: Arc<OnlineCalibrator>,
    rx: Receiver<Request>,
    metrics: Arc<Metrics>,
    pending: Arc<AtomicUsize>,
) {
    let n_in = routes.primary.ncols();
    let n_out = routes.primary.nrows();
    let bytes = routes.primary.byte_size();
    let wide_sink = TimingSink::new(routes.primary.timing_slots());
    let narrow_sink = TimingSink::new(routes.narrow.timing_slots());
    let mut carry = VecDeque::new();
    let mut samples: Vec<Sample> = Vec::new();
    while let Some(batch) =
        fill_class_batch(&rx, &mut carry, &policy, &|wait| panel_cap(&routes.primary, &cfg, &policy, wait))
    {
        let b = batch.len();
        let x = assemble_panel(n_in, &batch);
        let w = x.ncols();
        let op = routes.pick(w);
        let sink = if w == 1 { &narrow_sink } else { &wide_sink };
        sink.reset();
        let mut y = DMatrix::zeros(n_out, w);
        let t = Timer::start();
        op.apply_multi_timed(1.0, &x, &mut y, sink);
        let mvm_secs = t.elapsed();

        samples.clear();
        let (predicted, measured) = op.observe_multi(sink, w, &mut samples);
        calib.observe(&samples, predicted, measured);

        let latencies: Vec<f64> = batch.iter().map(|r| r.submitted.elapsed().as_secs_f64()).collect();
        metrics.record_batch(b, mvm_secs, bytes, &latencies);
        if let Some((hits, misses)) = routes.primary.cache_counters() {
            metrics.record_cache(hits, misses);
        }
        reply_ok(batch, &y, b, &pending);
    }
}

/// One batch in flight between the dispatcher and the gather thread.
struct Ticket {
    seq: u64,
    batch: Vec<Request>,
    timer: Timer,
}

/// Dispatcher-side adaptive context: the parent operator supplies the live
/// panel cost model for deadline packing, and every job runs timed.
struct AdaptiveDispatch {
    op: Arc<PlannedOperator>,
    cfg: OnlineConfig,
}

/// Scatter side of the sharded tier: batch requests (continuous per-class
/// batching when adaptive), broadcast the shared X panel to every shard's
/// bounded queue, post the gather ticket. Posting the ticket first lets the
/// gather thread overlap with shard compute.
fn dispatch_loop(
    n_in: usize,
    policy: BatchPolicy,
    adaptive: Option<AdaptiveDispatch>,
    rx: Receiver<Request>,
    jobs: Vec<SyncSender<ShardJob>>,
    tickets: Sender<Ticket>,
    counters: Vec<Arc<ShardCounters>>,
    fault: Arc<AtomicUsize>,
) {
    let mut seq = 0u64;
    let mut carry = VecDeque::new();
    loop {
        let batch = match &adaptive {
            Some(a) => fill_class_batch(&rx, &mut carry, &policy, &|wait| panel_cap(&a.op, &a.cfg, &policy, wait)),
            None => fill_batch(&rx, &policy),
        };
        let Some(batch) = batch else { return };
        let x = Arc::new(assemble_panel(n_in, &batch));
        if tickets.send(Ticket { seq, batch, timer: Timer::start() }).is_err() {
            return;
        }
        let failing = fault.swap(NO_FAULT, Ordering::AcqRel);
        // one wire-encoding slot per batch: remote couriers serialize the
        // shared X panel into it once, whichever shard's courier is first
        let wire = Arc::new(OnceLock::new());
        for (i, js) in jobs.iter().enumerate() {
            counters[i].enqueue();
            let job = ShardJob { seq, x: x.clone(), timed: adaptive.is_some(), fail: i == failing, wire: wire.clone() };
            match js.try_send(job) {
                Ok(()) => {}
                Err(TrySendError::Full(job)) => {
                    // bounded queue full: count the backpressure event, then
                    // block — admission control lives at the front door, so
                    // no work is dropped here
                    counters[i].backpressure();
                    if js.send(job).is_err() {
                        return;
                    }
                }
                Err(TrySendError::Disconnected(_)) => return,
            }
        }
        seq += 1;
    }
}

/// Gather side: for each ticket, collect every shard's owned rows **in fixed
/// shard order** from per-shard FIFO channels, reassemble Y (disjoint row
/// copies — bitwise deterministic), record metrics, reply. Runs one batch
/// behind the shards, overlapping gather with compute.
fn gather_loop(
    n_out: usize,
    bytes: usize,
    tickets: Receiver<Ticket>,
    results: Vec<Receiver<ShardResult>>,
    metrics: Arc<Metrics>,
    pending: Arc<AtomicUsize>,
    calib: Option<Arc<OnlineCalibrator>>,
) {
    while let Ok(t) = tickets.recv() {
        let b = t.batch.len();
        let w: usize = t.batch.iter().map(|r| r.payload.width()).sum();
        let mut y = DMatrix::zeros(n_out, w);
        let mut failure: Option<(usize, String)> = None;
        // per-shard timing harvests fold into ONE calibrator observation per
        // batch: samples concatenate, and the batch makespan is the max
        // across shards (they run the level barriers in parallel)
        let mut obs: Option<ShardObservation> = None;
        for (i, rx) in results.iter().enumerate() {
            let res = match rx.recv() {
                Ok(r) => r,
                Err(_) => {
                    if failure.is_none() {
                        failure = Some((i, "shard worker exited".to_string()));
                    }
                    continue;
                }
            };
            debug_assert_eq!(res.seq, t.seq, "per-shard FIFOs must stay in batch order");
            if let Some(part) = res.obs {
                match &mut obs {
                    None => obs = Some(part),
                    Some(agg) => {
                        agg.samples.extend(part.samples);
                        agg.predicted = agg.predicted.max(part.predicted);
                        agg.measured = agg.measured.max(part.measured);
                    }
                }
            }
            match res.out {
                Ok(part) => {
                    if failure.is_none() {
                        for c in 0..w {
                            y.col_mut(c)[res.rows.clone()].copy_from_slice(part.col(c));
                        }
                    }
                }
                Err(message) => {
                    if failure.is_none() {
                        failure = Some((i, message));
                    }
                }
            }
        }
        let mvm_secs = t.timer.elapsed();
        match failure {
            None => {
                if let (Some(c), Some(o)) = (&calib, obs) {
                    c.observe(&o.samples, o.predicted, o.measured);
                }
                let latencies: Vec<f64> = t.batch.iter().map(|r| r.submitted.elapsed().as_secs_f64()).collect();
                metrics.record_batch(b, mvm_secs, bytes, &latencies);
                let (mut hits, mut misses, mut any) = (0u64, 0u64, false);
                for sc in metrics.shard_counters() {
                    let s = sc.snapshot();
                    any |= s.cache_hits + s.cache_misses > 0;
                    hits += s.cache_hits;
                    misses += s.cache_misses;
                }
                if any {
                    metrics.record_cache(hits, misses);
                }
                reply_ok(t.batch, &y, b, &pending);
            }
            Some((shard, message)) => {
                for r in t.batch.into_iter() {
                    let _ = r.reply.send(Err(ServeError::ShardFailed { shard, message: message.clone() }));
                    pending.fetch_sub(1, Ordering::AcqRel);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{BlockTree, ClusterTree, StdAdmissibility};
    use crate::geometry::icosphere;
    use crate::hmatrix::HMatrix;
    use crate::kernelfn::{LaplaceSlp, MatrixGen};
    use crate::lowrank::AcaOptions;
    use crate::util::Rng;

    fn small_h() -> Arc<HMatrix> {
        let geom = icosphere(1);
        let gen = LaplaceSlp::new(&geom);
        let ct = Arc::new(ClusterTree::build(gen.points(), 8));
        let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
        Arc::new(HMatrix::build(&bt, &gen, &AcaOptions::with_eps(1e-6)))
    }

    #[test]
    fn serves_correct_results() {
        let h = small_h();
        let server = MvmServer::start(h.clone(), BatchPolicy::default());
        let mut rng = Rng::new(161);
        for _ in 0..5 {
            let x = rng.vector(h.ncols());
            let resp = server.call(x.clone());
            let mut want = vec![0.0; h.nrows()];
            crate::mvm::mvm(1.0, &h, &x, &mut want, crate::mvm::MvmAlgorithm::Seq);
            for i in 0..want.len() {
                assert!((resp.y[i] - want[i]).abs() < 1e-10);
            }
        }
        let snap = server.metrics.snapshot();
        assert_eq!(snap.requests, 5);
    }

    #[test]
    fn serves_external_ordering_requests_behind_plan() {
        // clients submit right-hand sides in the ORIGINAL point ordering; the
        // operator folds the cluster-tree permutations into the plan run
        let geom = icosphere(1);
        let gen = LaplaceSlp::new(&geom);
        let ct = Arc::new(ClusterTree::build(gen.points(), 8));
        let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
        let h = Arc::new(HMatrix::build(&bt, &gen, &AcaOptions::with_eps(1e-8)));
        let op = Arc::new(crate::plan::PlannedOperator::from_h(h.clone()).with_external_ordering());
        assert!(op.is_external_ordering());
        let server = MvmServer::start(op, BatchPolicy::default());
        let mut rng = Rng::new(163);
        for _ in 0..3 {
            let x_ext = rng.vector(h.ncols());
            let resp = server.call(x_ext.clone());
            // reference: permute manually, run internal MVM, permute back
            let xi = ct.to_internal(&x_ext);
            let mut yi = vec![0.0; h.nrows()];
            crate::mvm::mvm(1.0, &h, &xi, &mut yi, crate::mvm::MvmAlgorithm::Seq);
            let want = ct.to_external(&yi);
            for i in 0..want.len() {
                assert!((resp.y[i] - want[i]).abs() < 1e-10, "row {i}: {} vs {}", resp.y[i], want[i]);
            }
        }
    }

    #[test]
    fn serves_identically_on_every_executor_backend() {
        // same requests, one server per backend: responses must be bitwise
        // equal — the executor changes only the thread mapping
        let h = small_h();
        let mut rng = Rng::new(164);
        let xs: Vec<Vec<f64>> = (0..4).map(|_| rng.vector(h.ncols())).collect();
        let mut per_backend: Vec<Vec<Vec<f64>>> = Vec::new();
        for kind in crate::plan::ExecutorKind::all(2) {
            let op = Arc::new(crate::plan::PlannedOperator::from_h_with(h.clone(), kind));
            assert_eq!(op.executor_name(), kind.to_string());
            let server = MvmServer::start(op, BatchPolicy::default());
            per_backend.push(xs.iter().map(|x| server.call(x.clone()).y).collect());
        }
        for ys in &per_backend[1..] {
            for (a, b) in ys.iter().zip(&per_backend[0]) {
                for (va, vb) in a.iter().zip(b) {
                    assert_eq!(va.to_bits(), vb.to_bits());
                }
            }
        }
    }

    #[test]
    fn batches_concurrent_requests() {
        let h = small_h();
        let policy = BatchPolicy { max_batch: 16, linger: Duration::from_millis(20), ..BatchPolicy::default() };
        let server = Arc::new(MvmServer::start(h.clone(), policy));
        let mut rng = Rng::new(162);
        let xs: Vec<Vec<f64>> = (0..12).map(|_| rng.vector(h.ncols())).collect();
        let rxs: Vec<_> = xs.iter().map(|x| server.submit(x.clone())).collect();
        let resps: Vec<Response> = rxs.into_iter().map(|r| r.recv().unwrap().unwrap()).collect();
        // at least some requests must have shared a batch
        assert!(resps.iter().any(|r| r.batch_size > 1), "no batching happened");
        let snap = server.metrics.snapshot();
        assert_eq!(snap.requests, 12);
        assert!(snap.batches < 12);
    }

    #[test]
    fn panel_requests_match_single_calls_bitwise() {
        let h = small_h();
        let op = Arc::new(crate::plan::PlannedOperator::from_h(h.clone()));
        let server = MvmServer::start(op, BatchPolicy::default());
        let mut rng = Rng::new(166);
        let xs: Vec<Vec<f64>> = (0..3).map(|_| rng.vector(h.ncols())).collect();
        let singles: Vec<Vec<f64>> = xs.iter().map(|x| server.call(x.clone()).y).collect();
        let mut panel = DMatrix::zeros(h.ncols(), 3);
        for (c, x) in xs.iter().enumerate() {
            panel.col_mut(c).copy_from_slice(x);
        }
        let resp = server.call_panel(panel);
        assert_eq!(resp.ncols, 3);
        assert_eq!(resp.y.len(), h.nrows() * 3);
        for (c, w) in singles.iter().enumerate() {
            let got = &resp.y[c * h.nrows()..(c + 1) * h.nrows()];
            for (a, b) in got.iter().zip(w) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn adaptive_server_matches_static_bitwise_across_refits() {
        let h = small_h();
        let op = Arc::new(crate::plan::PlannedOperator::from_h(h.clone()));
        let mut rng = Rng::new(167);
        let xs: Vec<Vec<f64>> = (0..6).map(|_| rng.vector(h.ncols())).collect();
        let static_srv = MvmServer::start(op.clone(), BatchPolicy::default());
        let want: Vec<Vec<f64>> = xs.iter().map(|x| static_srv.call(x.clone()).y).collect();
        drop(static_srv);
        let cfg = OnlineConfig { min_samples: 1, ..Default::default() };
        let adaptive = MvmServer::start_adaptive(op, BatchPolicy::default(), cfg);
        for (i, (x, w)) in xs.iter().zip(&want).enumerate() {
            let got = adaptive.call(x.clone()).y;
            for (a, b) in got.iter().zip(w) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            if i % 2 == 1 {
                // forced mid-stream re-fit + packing swap between requests
                adaptive.calibrator().expect("adaptive server").force_refit();
            }
        }
        let st = adaptive.online_status().expect("adaptive server");
        assert!(st.observations >= 6, "every batch observes: {st:?}");
        assert!(st.refits >= 1, "forced refits must count: {st:?}");
    }

    #[test]
    fn sharded_adaptive_matches_unsharded_bitwise() {
        let h = small_h();
        let op = Arc::new(crate::plan::PlannedOperator::from_h_with(h.clone(), crate::plan::ExecutorKind::StaticLpt));
        let mut rng = Rng::new(168);
        let xs: Vec<Vec<f64>> = (0..4).map(|_| rng.vector(h.ncols())).collect();
        let flat = MvmServer::start(op.clone(), BatchPolicy::default());
        let want: Vec<Vec<f64>> = xs.iter().map(|x| flat.call(x.clone()).y).collect();
        drop(flat);
        let cfg = OnlineConfig { min_samples: 1, ..Default::default() };
        let sharded =
            MvmServer::start_sharded_adaptive(op, 2, crate::plan::ExecutorKind::StaticLpt, BatchPolicy::default(), cfg)
                .expect("adaptive sharded server starts");
        for (x, w) in xs.iter().zip(&want) {
            let got = sharded.call(x.clone()).y;
            for (a, b) in got.iter().zip(w) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        let st = sharded.online_status().expect("adaptive server");
        assert!(st.observations >= 4, "per-batch shard observations fold in: {st:?}");
    }

    #[test]
    fn sharded_server_matches_unsharded_bitwise() {
        let h = small_h();
        let op = Arc::new(crate::plan::PlannedOperator::from_h_with(h.clone(), crate::plan::ExecutorKind::StaticLpt));
        let mut rng = Rng::new(165);
        let xs: Vec<Vec<f64>> = (0..4).map(|_| rng.vector(h.ncols())).collect();
        let flat = MvmServer::start(op.clone(), BatchPolicy::default());
        let want: Vec<Vec<f64>> = xs.iter().map(|x| flat.call(x.clone()).y).collect();
        drop(flat);
        let sharded = MvmServer::start_sharded(op, 2, crate::plan::ExecutorKind::StaticLpt, BatchPolicy::default())
            .expect("sharded server starts");
        for (x, w) in xs.iter().zip(&want) {
            let got = sharded.call(x.clone()).y;
            for (a, b) in got.iter().zip(w) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        let line = sharded.metrics.shard_summary().expect("sharded metrics");
        assert!(line.starts_with("shards: 2"), "unexpected summary: {line}");
    }

    #[test]
    fn front_door_survives_panicking_clients() {
        // regression: request ids were ticked under a Mutex, so one client
        // thread panicking mid-submit poisoned the lock and every later
        // submit panicked on `.lock().unwrap()`. The atomic front door must
        // keep serving — and keep ids unique — after client panics.
        let h = small_h();
        let server = Arc::new(MvmServer::start(h.clone(), BatchPolicy::default()));
        let mut rng = Rng::new(169);
        let x = rng.vector(h.ncols());
        for _ in 0..3 {
            let (srv, xs) = (server.clone(), x.clone());
            let client = std::thread::spawn(move || {
                let _rx = srv.submit(xs);
                panic!("client dies after submitting");
            });
            assert!(client.join().is_err(), "client thread must have panicked");
        }
        // concurrent well-behaved clients still get served, with unique ids
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let (srv, xs) = (server.clone(), x.clone());
                std::thread::spawn(move || srv.try_call(xs).expect("front door must keep serving").id)
            })
            .collect();
        let mut ids: Vec<u64> = handles.into_iter().map(|h| h.join().expect("no panic")).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 4, "request ids must stay unique");
    }
}
