//! Cross-process shard fleet: the socket tier of the scatter/gather
//! coordinator.
//!
//! The in-process sharded tier already speaks a message protocol —
//! `ShardJob` down per-shard bounded queues, `ShardResult` back up FIFO
//! channels. This module carries the same messages across a process
//! boundary, one TCP connection per shard:
//!
//! ```text
//!   dispatcher ── ShardJob ──▶ courier 0 ══ TCP ══▶ shard-worker process 0
//!              ── ShardJob ──▶ courier 1 ══ TCP ══▶ shard-worker process 1
//!   gather     ◀─ ShardResult ─ courier i ◀═══════  (owned rows of Y)
//! ```
//!
//! Each **courier** thread replaces one in-process shard worker: it owns
//! the connection to its worker, encodes each batch's Job frame **once**
//! (the buffer is shared across shards through the job's `OnceLock`, and
//! retained for replay), keeps up to [`RemoteConfig::pipeline`] jobs in
//! flight so socket writes overlap worker compute, and forwards results to
//! the gather thread — which cannot tell couriers from local workers, so
//! the served Y stays **bitwise identical** to in-process sharded serving.
//!
//! Robustness is the courier's whole job: connect/read/write timeouts,
//! capped exponential-backoff reconnect, Ping/Pong heartbeats on idle
//! connections, and in-flight **job replay** after a reconnect (results are
//! deterministic, so recomputing a lost job returns the same bits). Only
//! after [`RemoteConfig::max_attempts`] consecutive failed connects does a
//! batch surface as [`super::ServeError::ShardFailed`] — the remote
//! generalization of the `catch_unwind` containment of the local tier.
//!
//! The **worker** side ([`serve_worker`], behind `hmatc shard-worker`) is a
//! deliberately simple synchronous accept loop: one trusted coordinator at
//! a time, handshake (version + operator dims), an Assign that pins the
//! shard's row slice, then Job→Result until EOF. It keeps no read
//! timeouts — the courier's heartbeats keep the link busy — and caches its
//! built [`ShardPlan`] across reconnects of the same assignment.

use super::metrics::ShardCounters;
use super::shard::{panic_message, ShardJob, ShardResult};
use super::wire::{
    assign_frame, encode_frame, encode_job, read_frame, spec_from_assign, write_frame, Frame, WireError, WIRE_VERSION,
};
use crate::la::DMatrix;
use crate::plan::{ExecutorKind, PlannedOperator, ShardPlan, ShardSpec};
use crate::store::HotCache;
use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Timeout, backoff, and pipelining knobs of the remote tier.
#[derive(Clone, Debug)]
pub struct RemoteConfig {
    /// TCP connect timeout per attempt.
    pub connect_timeout: Duration,
    /// Socket read/write timeout. Must exceed the worst-case batch compute
    /// of one worker — a slower worker looks dead and triggers a reconnect.
    pub io_timeout: Duration,
    /// Idle heartbeat period: with no job in flight, the courier pings the
    /// worker (or probes a reconnect) this often. Heartbeats never run with
    /// jobs in flight, so long computes cause no spurious timeouts.
    pub heartbeat: Duration,
    /// Initial reconnect backoff, doubled per consecutive failure.
    pub backoff: Duration,
    /// Backoff cap.
    pub backoff_max: Duration,
    /// Consecutive failed connect attempts before the in-flight jobs fail
    /// over to [`super::ServeError::ShardFailed`] (the courier then keeps
    /// trying for subsequent jobs — a returning worker resumes service).
    pub max_attempts: u32,
    /// Jobs kept in flight per shard connection, overlapping socket writes
    /// with worker compute (the worker computes them in order).
    pub pipeline: usize,
}

impl Default for RemoteConfig {
    fn default() -> Self {
        RemoteConfig {
            connect_timeout: Duration::from_secs(1),
            io_timeout: Duration::from_secs(10),
            heartbeat: Duration::from_millis(500),
            backoff: Duration::from_millis(50),
            backoff_max: Duration::from_secs(2),
            max_attempts: 5,
            pipeline: 2,
        }
    }
}

/// All courier socket I/O goes through this wrapper so the per-shard
/// network byte counters see every frame, handshake and heartbeat included.
struct Meter<'a> {
    s: &'a TcpStream,
    counters: &'a ShardCounters,
}

impl Read for Meter<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.s.read(buf)?;
        self.counters.add_rx(n as u64);
        Ok(n)
    }
}

impl Write for Meter<'_> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let n = self.s.write(buf)?;
        self.counters.add_tx(n as u64);
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.s.flush()
    }
}

/// Connect + handshake: Hello/HelloAck (version and operator dims validated
/// both ways), then Assign/AssignAck pinning the shard's row slice.
fn connect_handshake(addr: &str, spec: &ShardSpec, dims: (u64, u64), cfg: &RemoteConfig) -> Result<TcpStream, String> {
    let sock = addr
        .to_socket_addrs()
        .map_err(|e| format!("bad worker address {addr}: {e}"))?
        .next()
        .ok_or_else(|| format!("worker address {addr} resolves to nothing"))?;
    let stream = TcpStream::connect_timeout(&sock, cfg.connect_timeout).map_err(|e| format!("connect {addr}: {e}"))?;
    stream.set_nodelay(true).map_err(|e| format!("set_nodelay {addr}: {e}"))?;
    stream.set_read_timeout(Some(cfg.io_timeout)).map_err(|e| format!("set timeouts {addr}: {e}"))?;
    stream.set_write_timeout(Some(cfg.io_timeout)).map_err(|e| format!("set timeouts {addr}: {e}"))?;
    let mut s = &stream;
    write_frame(&mut s, &Frame::Hello { version: WIRE_VERSION, nrows: dims.0, ncols: dims.1 })
        .map_err(|e| format!("handshake write {addr}: {e}"))?;
    match read_frame(&mut s) {
        Ok(Frame::HelloAck { version, nrows, ncols }) => {
            if version != WIRE_VERSION {
                return Err(format!("worker {addr} speaks wire version {version}, this coordinator speaks {WIRE_VERSION}"));
            }
            if (nrows, ncols) != dims {
                return Err(format!("worker {addr} serves a {nrows}x{ncols} operator, expected {}x{}", dims.0, dims.1));
            }
        }
        Ok(f) => return Err(format!("worker {addr} answered the handshake with {f:?}")),
        Err(e) => return Err(format!("handshake read {addr}: {e}")),
    }
    write_frame(&mut s, &assign_frame(spec)).map_err(|e| format!("assign write {addr}: {e}"))?;
    match read_frame(&mut s) {
        Ok(Frame::AssignAck) => Ok(stream),
        Ok(f) => Err(format!("worker {addr} answered the assignment with {f:?}")),
        Err(e) => Err(format!("assign read {addr}: {e}")),
    }
}

/// One job the courier has admitted but not yet resolved. The encoded Job
/// frame lives in the `ShardJob`'s `OnceLock`, shared by every shard's
/// courier (the panel is encoded once per batch) and kept until the result
/// arrives so a reconnect can replay it byte-identically.
struct Pending {
    seq: u64,
    x: Arc<DMatrix>,
    frame: Arc<std::sync::OnceLock<Vec<u8>>>,
    /// Fault injection: ask the worker to drop the connection before this
    /// job (one-shot — cleared after sending so the replay computes).
    crash: bool,
    sent: bool,
}

/// Courier thread of one remote shard: same channel contract as the
/// in-process `shard_worker`, with the compute on the far side of a socket.
pub(crate) fn courier_loop(
    addr: String,
    spec: ShardSpec,
    dims: (u64, u64),
    cfg: RemoteConfig,
    jobs: Receiver<ShardJob>,
    results: Sender<ShardResult>,
    counters: Arc<ShardCounters>,
) {
    let owned = spec.rows.clone();
    let mut conn: Option<TcpStream> = None;
    let mut inflight: VecDeque<Pending> = VecDeque::new();
    let mut backoff = cfg.backoff;
    let mut fails = 0u32;
    let mut first_attempt = true;
    let mut draining = false;
    let pipeline = cfg.pipeline.max(1);
    loop {
        // (A) admit: block for work when idle; the timeout doubles as the
        // heartbeat tick (ping a live connection, probe a dead one).
        if inflight.is_empty() && !draining {
            match jobs.recv_timeout(cfg.heartbeat) {
                Ok(job) => {
                    counters.start();
                    inflight.push_back(admit(job));
                }
                Err(RecvTimeoutError::Timeout) => {
                    let dead = match &conn {
                        Some(s) => match heartbeat(s, &counters) {
                            Ok(()) => false,
                            Err(e) => {
                                if e.is_timeout() {
                                    counters.net_timeout();
                                }
                                true
                            }
                        },
                        None => false,
                    };
                    if dead {
                        conn = None;
                    }
                    if conn.is_some() {
                        continue;
                    }
                    // fall through with an empty inflight: the probe branch
                    // below attempts one reconnect per heartbeat tick so a
                    // restarted fleet is re-linked before the next batch
                }
                Err(RecvTimeoutError::Disconnected) => draining = true,
            }
        }
        // top the pipeline up without blocking
        while inflight.len() < pipeline && !draining {
            match jobs.try_recv() {
                Ok(job) => {
                    counters.start();
                    inflight.push_back(admit(job));
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => draining = true,
            }
        }
        if inflight.is_empty() {
            if draining {
                return;
            }
            if conn.is_some() {
                continue;
            }
            // idle probe: one connect attempt per heartbeat tick, no backoff
            if !first_attempt {
                counters.reconnect();
            }
            first_attempt = false;
            if let Ok(s) = connect_handshake(&addr, &spec, dims, &cfg) {
                conn = Some(s);
                backoff = cfg.backoff;
                fails = 0;
            }
            continue;
        }
        // (B) ensure a live connection; on repeated failure, fail the
        // in-flight jobs over to the gather thread instead of wedging
        if conn.is_none() {
            if !first_attempt {
                counters.reconnect();
            }
            first_attempt = false;
            match connect_handshake(&addr, &spec, dims, &cfg) {
                Ok(s) => {
                    conn = Some(s);
                    backoff = cfg.backoff;
                    fails = 0;
                    for p in &mut inflight {
                        p.sent = false;
                    }
                }
                Err(e) => {
                    fails += 1;
                    if fails >= cfg.max_attempts.max(1) {
                        fails = 0;
                        backoff = cfg.backoff;
                        for p in inflight.drain(..) {
                            counters.finish();
                            let out = Err(format!("worker {addr} unreachable after {} attempts: {e}", cfg.max_attempts));
                            if results.send(ShardResult { seq: p.seq, rows: owned.clone(), out, obs: None }).is_err() {
                                return;
                            }
                        }
                    } else {
                        std::thread::sleep(backoff);
                        backoff = (backoff * 2).min(cfg.backoff_max);
                    }
                    continue;
                }
            }
        }
        let stream = conn.take().expect("connection established above");
        let mut alive = true;
        // (C) send every unsent job in order (replays included)
        for p in inflight.iter_mut().filter(|p| !p.sent) {
            let mut m = Meter { s: &stream, counters: &counters };
            if p.crash {
                p.crash = false;
                if write_frame(&mut m, &Frame::Crash).is_err() {
                    alive = false;
                    break;
                }
            }
            let bytes = p.frame.get_or_init(|| encode_job(p.seq, false, &p.x));
            if m.write_all(bytes).is_err() {
                alive = false;
                break;
            }
            p.sent = true;
        }
        if !alive {
            continue;
        }
        // (D) read one frame; a timeout or error drops the connection and
        // marks the in-flight jobs for replay
        let mut m = Meter { s: &stream, counters: &counters };
        match read_frame(&mut m) {
            Ok(Frame::Result { seq, rows, out }) => {
                let front = inflight.front().expect("inflight nonempty");
                if seq == front.seq {
                    let p = inflight.pop_front().expect("checked front");
                    counters.round_trip();
                    counters.finish();
                    let rows = decode_rows(rows).unwrap_or_else(|| owned.clone());
                    let out = out.map(|part| {
                        debug_assert_eq!((part.nrows(), part.ncols()), (rows.len(), p.x.ncols()));
                        part
                    });
                    if results.send(ShardResult { seq, rows, out, obs: None }).is_err() {
                        return;
                    }
                } else {
                    // worker answered out of order — protocol violation;
                    // drop the connection and replay
                    alive = false;
                }
            }
            Ok(Frame::Pong) => {}
            Ok(_) => alive = false,
            Err(e) => {
                if e.is_timeout() {
                    counters.net_timeout();
                }
                alive = false;
            }
        }
        if alive {
            conn = Some(stream);
        } else {
            for p in &mut inflight {
                p.sent = false;
            }
        }
    }
}

fn admit(job: ShardJob) -> Pending {
    Pending { seq: job.seq, x: job.x, frame: job.wire, crash: job.fail, sent: false }
}

fn decode_rows(rows: (u64, u64)) -> Option<Range<usize>> {
    let start = usize::try_from(rows.0).ok()?;
    let end = usize::try_from(rows.1).ok()?;
    (start <= end).then_some(start..end)
}

/// Ping the worker and wait for the Pong (idle connections only).
fn heartbeat(stream: &TcpStream, counters: &ShardCounters) -> Result<(), WireError> {
    let mut m = Meter { s: stream, counters };
    write_frame(&mut m, &Frame::Ping).map_err(WireError::Io)?;
    match read_frame(&mut m) {
        Ok(Frame::Pong) => Ok(()),
        Ok(f) => Err(WireError::Protocol(format!("expected pong, got {f:?}"))),
        Err(e) => Err(e),
    }
}

/// Serve shard jobs over TCP until the process is killed (or, with
/// `exit_after_jobs`, until the quota is reached — the deterministic
/// crash-simulation hook of the fleet tests and the CI smoke). One trusted
/// coordinator connection at a time; the built [`ShardPlan`] is cached
/// across reconnects of the same assignment.
pub fn serve_worker(
    listener: TcpListener,
    op: Arc<PlannedOperator>,
    kind: ExecutorKind,
    exit_after_jobs: Option<u64>,
) -> Result<(), String> {
    if op.is_external_ordering() {
        return Err("shard workers take internal-ordering operators (drop with_external_ordering)".to_string());
    }
    let dims = (op.nrows() as u64, op.ncols() as u64);
    let mut plan: Option<(ShardSpec, Arc<ShardPlan>)> = None;
    let mut served = 0u64;
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                eprintln!("shard-worker: accept failed: {e}");
                continue;
            }
        };
        let _ = stream.set_nodelay(true);
        match serve_connection(&stream, &op, kind, dims, &mut plan, &mut served, exit_after_jobs) {
            ConnExit::Quota => return Ok(()),
            ConnExit::Dropped => {}
            ConnExit::Rejected(why) => eprintln!("shard-worker: dropped connection: {why}"),
        }
    }
    Ok(())
}

enum ConnExit {
    /// `exit_after_jobs` reached: the worker process exits cleanly.
    Quota,
    /// Peer went away (EOF) or asked for a simulated crash.
    Dropped,
    /// Protocol violation — logged, connection dropped, worker keeps serving.
    Rejected(String),
}

fn serve_connection(
    stream: &TcpStream,
    op: &Arc<PlannedOperator>,
    kind: ExecutorKind,
    dims: (u64, u64),
    plan: &mut Option<(ShardSpec, Arc<ShardPlan>)>,
    served: &mut u64,
    exit_after_jobs: Option<u64>,
) -> ConnExit {
    let mut s = stream;
    // handshake: a wrong-version or wrong-operator coordinator is rejected
    // before any work frame is interpreted
    match read_frame(&mut s) {
        Ok(Frame::Hello { version, nrows, ncols }) => {
            if version != WIRE_VERSION {
                return ConnExit::Rejected(format!("peer speaks wire version {version}, this worker speaks {WIRE_VERSION}"));
            }
            if (nrows, ncols) != dims {
                return ConnExit::Rejected(format!(
                    "peer expects a {nrows}x{ncols} operator, this worker serves {}x{}",
                    dims.0, dims.1
                ));
            }
            if write_frame(&mut s, &Frame::HelloAck { version: WIRE_VERSION, nrows: dims.0, ncols: dims.1 }).is_err() {
                return ConnExit::Dropped;
            }
        }
        Ok(f) => return ConnExit::Rejected(format!("expected hello, got {f:?}")),
        Err(WireError::Closed) => return ConnExit::Dropped,
        Err(e) => return ConnExit::Rejected(e.to_string()),
    }
    loop {
        match read_frame(&mut s) {
            Ok(Frame::Assign { index, count, rows, cols }) => {
                let spec = match spec_from_assign(index, count, rows, cols) {
                    Ok(sp) => sp,
                    Err(e) => return ConnExit::Rejected(e.to_string()),
                };
                if spec.rows.end > op.nrows() || spec.cols.end > op.ncols() {
                    return ConnExit::Rejected(format!(
                        "assignment {:?}/{:?} exceeds the {}x{} operator",
                        spec.rows,
                        spec.cols,
                        op.nrows(),
                        op.ncols()
                    ));
                }
                let reuse = plan.as_ref().is_some_and(|(have, _)| {
                    have.index == spec.index && have.count == spec.count && have.rows == spec.rows && have.cols == spec.cols
                });
                if !reuse {
                    let built = Arc::new(ShardPlan::build(op, spec.clone(), kind));
                    // shard-local decode-once cache, exactly like the
                    // in-process tier
                    built.set_hot_cache(HotCache::from_env());
                    *plan = Some((spec, built));
                }
                if write_frame(&mut s, &Frame::AssignAck).is_err() {
                    return ConnExit::Dropped;
                }
            }
            Ok(Frame::Job { seq, adjoint, x }) => {
                let Some((_, sp)) = plan.as_ref() else {
                    return ConnExit::Rejected("job before assignment".to_string());
                };
                let want = if adjoint { op.nrows() } else { op.ncols() };
                if x.nrows() != want {
                    return ConnExit::Rejected(format!("job panel has {} rows, operator wants {want}", x.nrows()));
                }
                let rows = sp.owned(adjoint);
                let sp = sp.clone();
                let out = catch_unwind(AssertUnwindSafe(|| {
                    let mut out = DMatrix::zeros(rows.len(), x.ncols());
                    sp.apply_multi_owned(adjoint, 1.0, &x, None, &mut out);
                    out
                }))
                .map_err(|p| panic_message(p.as_ref()));
                let frame = Frame::Result { seq, rows: (rows.start as u64, rows.end as u64), out };
                if s.write_all(&encode_frame(&frame)).is_err() {
                    return ConnExit::Dropped;
                }
                *served += 1;
                if exit_after_jobs.is_some_and(|quota| *served >= quota) {
                    return ConnExit::Quota;
                }
            }
            Ok(Frame::Ping) => {
                if write_frame(&mut s, &Frame::Pong).is_err() {
                    return ConnExit::Dropped;
                }
            }
            Ok(Frame::Crash) => return ConnExit::Dropped,
            Ok(f) => return ConnExit::Rejected(format!("unexpected frame {f:?}")),
            Err(WireError::Closed) => return ConnExit::Dropped,
            Err(e) => return ConnExit::Rejected(e.to_string()),
        }
    }
}

/// A direct single-shard client over the same handshake and Job/Result
/// frames the couriers use — the protocol-level test surface (adjoint jobs,
/// per-shard calls) without standing up a full coordinator.
pub struct RemoteShardClient {
    stream: TcpStream,
    spec: ShardSpec,
}

impl RemoteShardClient {
    /// Connect to a worker and assign it `spec`.
    pub fn connect(addr: &str, spec: &ShardSpec, dims: (u64, u64), cfg: &RemoteConfig) -> Result<RemoteShardClient, String> {
        let stream = connect_handshake(addr, spec, dims, cfg)?;
        Ok(RemoteShardClient { stream, spec: spec.clone() })
    }

    /// The assigned shard spec.
    pub fn spec(&self) -> &ShardSpec {
        &self.spec
    }

    /// Run one job: ship the panel, return the worker's owned rows of the
    /// product (row range + row-sliced panel, bitwise exact).
    pub fn call(&mut self, seq: u64, x: &DMatrix, adjoint: bool) -> Result<(Range<usize>, DMatrix), String> {
        let mut s = &self.stream;
        s.write_all(&encode_job(seq, adjoint, x)).map_err(|e| format!("job write: {e}"))?;
        match read_frame(&mut s) {
            Ok(Frame::Result { seq: got, rows, out }) => {
                if got != seq {
                    return Err(format!("result for job {got}, expected {seq}"));
                }
                let rows = decode_rows(rows).ok_or_else(|| format!("bad result row range {rows:?}"))?;
                out.map(|m| (rows, m))
            }
            Ok(f) => Err(format!("expected result, got {f:?}")),
            Err(e) => Err(format!("result read: {e}")),
        }
    }
}

/// Bind a TCP listener with `SO_REUSEADDR`, so a restarted worker can
/// rebind its address while the old connection sits in TIME_WAIT — std's
/// `TcpListener::bind` does not set the option, which would make every
/// health-checked restart fail for a kernel-imposed minute.
pub fn bind_listener(addr: &str) -> Result<TcpListener, String> {
    #[cfg(target_os = "linux")]
    if let Ok(v4) = addr.parse::<std::net::SocketAddrV4>() {
        return sys::bind_reuse(v4);
    }
    TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))
}

/// [`bind_listener`] with retry: keep attempting for up to `wait` (100 ms
/// apart) before giving up — covers the restart race where the dying
/// worker's socket is still bound.
pub fn bind_listener_retry(addr: &str, wait: Duration) -> Result<TcpListener, String> {
    let deadline = Instant::now() + wait;
    loop {
        match bind_listener(addr) {
            Ok(l) => return Ok(l),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(e);
                }
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
}

// Raw Linux socket syscalls for the SO_REUSEADDR bind. std already links
// libc, so plain `extern "C"` declarations suffice — same pattern as
// `par::topology::sys` and `store::sys`.
#[cfg(target_os = "linux")]
mod sys {
    use std::net::{SocketAddrV4, TcpListener};
    use std::os::fd::FromRawFd;

    extern "C" {
        fn socket(domain: i32, ty: i32, protocol: i32) -> i32;
        fn setsockopt(fd: i32, level: i32, optname: i32, optval: *const i32, optlen: u32) -> i32;
        fn bind(fd: i32, addr: *const SockaddrIn, len: u32) -> i32;
        fn listen(fd: i32, backlog: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    /// `struct sockaddr_in`: port and address in network byte order.
    #[repr(C)]
    struct SockaddrIn {
        family: u16,
        port: u16,
        addr: u32,
        zero: [u8; 8],
    }

    const AF_INET: i32 = 2;
    const SOCK_STREAM: i32 = 1;
    const SOCK_CLOEXEC: i32 = 0x80000;
    const SOL_SOCKET: i32 = 1;
    const SO_REUSEADDR: i32 = 2;

    pub fn bind_reuse(v4: SocketAddrV4) -> Result<TcpListener, String> {
        unsafe {
            let fd = socket(AF_INET, SOCK_STREAM | SOCK_CLOEXEC, 0);
            if fd < 0 {
                return Err("socket() failed".to_string());
            }
            let one: i32 = 1;
            if setsockopt(fd, SOL_SOCKET, SO_REUSEADDR, &one, 4) != 0 {
                close(fd);
                return Err("setsockopt(SO_REUSEADDR) failed".to_string());
            }
            let sa = SockaddrIn {
                family: AF_INET as u16,
                port: v4.port().to_be(),
                addr: u32::from(*v4.ip()).to_be(),
                zero: [0; 8],
            };
            if bind(fd, &sa, std::mem::size_of::<SockaddrIn>() as u32) != 0 {
                close(fd);
                return Err(format!("bind {v4} failed (address in use?)"));
            }
            if listen(fd, 128) != 0 {
                close(fd);
                return Err(format!("listen {v4} failed"));
            }
            // from_raw_fd transfers ownership: the listener closes the fd
            Ok(TcpListener::from_raw_fd(fd))
        }
    }
}
