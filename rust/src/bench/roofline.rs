//! Roofline measurement (paper Fig. 7/14): the MVM algorithms are memory
//! bandwidth limited, so "% of peak" means percentage of the *measured*
//! STREAM-like bandwidth, at the kernel's arithmetic intensity.

use super::runner::bench_fn;

/// One point for a roofline plot.
#[derive(Clone, Copy, Debug)]
pub struct RooflinePoint {
    /// Arithmetic intensity in flop/byte.
    pub intensity: f64,
    /// Achieved performance in Gflop/s.
    pub gflops: f64,
    /// Achievable performance at this intensity given the measured peak
    /// bandwidth (bandwidth · intensity), in Gflop/s.
    pub roof_gflops: f64,
}

impl RooflinePoint {
    /// Fraction of the bandwidth roof achieved (the paper's ~80 % / ~60 %).
    pub fn fraction_of_peak(&self) -> f64 {
        if self.roof_gflops > 0.0 {
            (self.gflops / self.roof_gflops).min(10.0)
        } else {
            0.0
        }
    }
}

/// Measure sustainable memory bandwidth (GB/s) with a parallel triad
/// a[i] = b[i] + s·c[i] over a working set far larger than LLC.
pub fn measure_peak_bandwidth() -> f64 {
    let n = 1 << 24; // 3 × 128 MiB working set
    let b = vec![1.0f64; n];
    let c = vec![2.0f64; n];
    let mut a = vec![0.0f64; n];
    let nthreads = (crate::par::num_threads() + 1).max(1);
    let chunk = n.div_ceil(nthreads);
    let r = bench_fn(1, 5, 0.05, || {
        let b = &b;
        let c = &c;
        let chunks: Vec<&mut [f64]> = a.chunks_mut(chunk).collect();
        crate::par::ThreadPool::global().scope(|s| {
            for (t, ac) in chunks.into_iter().enumerate() {
                s.spawn(move |_| {
                    let off = t * chunk;
                    for i in 0..ac.len() {
                        ac[i] = b[off + i] + 0.5 * c[off + i];
                    }
                });
            }
        });
    });
    // triad moves 3 doubles per element (2 loads + 1 store)
    let bytes = 3.0 * 8.0 * n as f64;
    bytes / r.median / 1e9
}

/// Build a roofline point from measured time, flops and bytes moved.
pub fn roofline_point(seconds: f64, flops: f64, bytes: f64, peak_bw_gbs: f64) -> RooflinePoint {
    let intensity = if bytes > 0.0 { flops / bytes } else { 0.0 };
    let gflops = if seconds > 0.0 { flops / seconds / 1e9 } else { 0.0 };
    RooflinePoint { intensity, gflops, roof_gflops: peak_bw_gbs * intensity }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roofline_point_math() {
        let p = roofline_point(1.0, 2e9, 1e9, 10.0);
        assert!((p.intensity - 2.0).abs() < 1e-12);
        assert!((p.gflops - 2.0).abs() < 1e-12);
        assert!((p.roof_gflops - 20.0).abs() < 1e-12);
        assert!((p.fraction_of_peak() - 0.1).abs() < 1e-12);
    }

    #[test]
    #[ignore] // slow: allocates 384 MiB and saturates memory — run with --ignored
    fn bandwidth_positive() {
        let bw = measure_peak_bandwidth();
        assert!(bw > 0.5, "bw {bw} GB/s");
    }
}
