//! Benchmark substrate: mini-criterion sampling, roofline measurement, table
//! output and JSON result files (no criterion crate in the sandbox).

mod roofline;
mod runner;
mod table;
pub mod workloads;

pub use roofline::{measure_peak_bandwidth, roofline_point, RooflinePoint};
pub use runner::{bench_fn, cost_source_label, exec_context, BenchResult};
pub use table::Table;

use crate::util::json::Json;

/// Write one JSON result document under `bench_results/` (created on demand).
pub fn write_result(name: &str, doc: &Json) {
    let dir = std::path::Path::new("bench_results");
    if std::fs::create_dir_all(dir).is_err() {
        return;
    }
    let path = dir.join(format!("{name}.json"));
    let _ = std::fs::write(path, with_context(doc).to_string());
}

/// Write a machine-readable result file `BENCH_<tag>.json` in the working
/// directory — a stable filename the perf-trajectory tooling scrapes across
/// runs (in addition to the archive under `bench_results/`). Every document
/// is stamped with the run's `executor` and `threads` ([`exec_context`]) so
/// rows from different executor/thread configurations stay distinguishable.
pub fn write_bench_json(tag: &str, doc: &Json) {
    let _ = std::fs::write(format!("BENCH_{tag}.json"), with_context(doc).to_string());
}

/// Stamp `executor` + `threads` + `cost_source` + `topology` into the top
/// level of a result document (non-object documents are wrapped as
/// `{"data": ..}`). The topology object mirrors the calibration profile's
/// [`crate::plan::costmodel::TopologyMeta`] fingerprint so NUMA and
/// non-NUMA rows stay distinguishable in the perf trajectory.
fn with_context(doc: &Json) -> Json {
    let (executor, threads) = exec_context();
    let mut m = match doc.clone() {
        Json::Obj(m) => m,
        other => std::collections::BTreeMap::from([("data".to_string(), other)]),
    };
    m.insert("executor".to_string(), Json::Str(executor));
    m.insert("threads".to_string(), Json::Num(threads as f64));
    m.insert("cost_source".to_string(), Json::Str(cost_source_label()));
    let topo = crate::par::Topology::get();
    m.insert(
        "topology".to_string(),
        Json::Obj(std::collections::BTreeMap::from([
            ("nodes".to_string(), Json::Num(topo.num_nodes() as f64)),
            ("cores_per_node".to_string(), Json::Num(topo.cores_per_node() as f64)),
            ("pinned".to_string(), Json::Bool(topo.pin_enabled())),
        ])),
    );
    Json::Obj(m)
}

/// Standard benchmark problem sizes (icosphere levels → n = 20·4^level).
/// The default keeps a full `cargo bench` sweep feasible on this single-core
/// sandbox; pass `--large` (or set `HMATC_BENCH_LARGE=1`) for the paper-style
/// larger sizes.
pub fn default_levels(large: bool) -> Vec<usize> {
    if large || std::env::var("HMATC_BENCH_LARGE").is_ok() {
        vec![2, 3, 4, 5] // 320 … 20480
    } else {
        vec![2, 3, 4] // 320, 1280, 5120
    }
}

/// Standard accuracy sweep of the paper's figures.
pub fn default_eps() -> Vec<f64> {
    vec![1e-4, 1e-6, 1e-8]
}
