//! Timing loop: warmup + sampling with median/MAD statistics, plus the
//! execution context stamped into every benchmark result file.

use crate::util::{stats, Timer};

/// The run's execution context: default plan-execution backend (from
/// `HMATC_EXEC`) and total thread count (workers + helping scope thread).
/// [`crate::bench::write_bench_json`] stamps both — plus
/// [`cost_source_label`] — into every `BENCH_*.json` document so
/// perf-trajectory rows are comparable across executor/thread/cost-model
/// configurations.
pub fn exec_context() -> (String, usize) {
    (crate::plan::ExecutorKind::from_env().to_string(), crate::par::num_threads() + 1)
}

/// Cost-source label stamped into bench result documents: `online` when
/// `HMATC_ONLINE` enables the adaptive serving loop (the run re-fits its own
/// model, so any `HMATC_COSTS` file is only its starting point), else
/// `calibrated(<path>)` when `HMATC_COSTS` names a profile that actually
/// **loads and re-balances** (a file the plans reject falls back to static
/// costs, and the label must say so — otherwise static-cost rows would be
/// recorded as calibrated and corrupt the trajectory comparison), else
/// `static`. (Benches that calibrate in-process, e.g. the fig06/fig13
/// `plan calibrated` rows, label those rows themselves.)
pub fn cost_source_label() -> String {
    if crate::coordinator::OnlineConfig::enabled_from_env() {
        return "online".to_string();
    }
    crate::plan::costmodel::source_label(crate::plan::costmodel::costs_from_env().as_ref())
}

/// Result of a timed benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub samples: Vec<f64>,
    pub median: f64,
    pub mad: f64,
    pub min: f64,
}

impl BenchResult {
    /// Throughput in ops/s given work per invocation.
    pub fn rate(&self, work: f64) -> f64 {
        if self.median > 0.0 {
            work / self.median
        } else {
            0.0
        }
    }
}

/// Run `f` with warmup, then collect `samples` timed runs (each possibly
/// iterated so one sample lasts ≥ `min_sample_secs`).
pub fn bench_fn(warmup: usize, samples: usize, min_sample_secs: f64, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    // calibrate inner iterations
    let t = Timer::start();
    f();
    let once = t.elapsed().max(1e-9);
    let iters = (min_sample_secs / once).ceil().max(1.0) as usize;

    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples.max(1) {
        let t = Timer::start();
        for _ in 0..iters {
            f();
        }
        out.push(t.elapsed() / iters as f64);
    }
    BenchResult { median: stats::median(&out), mad: stats::mad(&out), min: stats::min(&out), samples: out }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut acc = 0u64;
        let r = bench_fn(1, 5, 0.001, || {
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
        });
        assert!(r.median > 0.0);
        assert!(r.min <= r.median);
        assert_eq!(r.samples.len(), 5);
        assert!(acc != 12345); // keep the accumulator alive
    }
}
