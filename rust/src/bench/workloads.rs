//! Shared benchmark workloads: the paper's model problem at standard sizes.

use crate::cluster::{BlockTree, ClusterTree, StdAdmissibility};
use crate::geometry::icosphere;
use crate::h2::H2Matrix;
use crate::hmatrix::HMatrix;
use crate::kernelfn::{LaplaceSlp, MatrixGen};
use crate::lowrank::AcaOptions;
use crate::uniform::{CouplingKind, UniformHMatrix};
use std::sync::Arc;

/// The BEM model problem (Laplace SLP on the unit sphere) at a given
/// icosphere level, clustered with n_min = 64, η = 2 (paper defaults).
pub struct Problem {
    pub gen: LaplaceSlp,
    pub bt: Arc<BlockTree>,
    pub level: usize,
}

impl Problem {
    pub fn new(level: usize) -> Problem {
        let geom = icosphere(level);
        let gen = LaplaceSlp::new(&geom);
        let ct = Arc::new(ClusterTree::build(gen.points(), 64));
        let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
        Problem { gen, bt, level }
    }

    pub fn n(&self) -> usize {
        self.gen.len()
    }

    pub fn build_h(&self, eps: f64) -> HMatrix {
        HMatrix::build(&self.bt, &self.gen, &AcaOptions::with_eps(eps))
    }
}

/// All three formats of the same operator.
pub struct Formats {
    pub h: HMatrix,
    pub uh: UniformHMatrix,
    pub h2: H2Matrix,
}

impl Formats {
    pub fn build(p: &Problem, eps: f64) -> Formats {
        let h = p.build_h(eps);
        let uh = crate::uniform::build_from_h(&h, eps, CouplingKind::Combined);
        let h2 = crate::h2::build_from_h(&h, eps);
        Formats { h, uh, h2 }
    }
}

/// Icosphere level → n (20·4^level).
pub fn level_n(level: usize) -> usize {
    20 * 4usize.pow(level as u32)
}
