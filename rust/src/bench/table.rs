//! Plain-text table printing for bench outputs (paper-style rows).

/// A simple column-aligned table.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render with column alignment.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize], out: &mut String| {
            for i in 0..ncols {
                if i > 0 {
                    out.push_str("  ");
                }
                out.push_str(&format!("{:>w$}", cells[i], w = widths[i]));
            }
            out.push('\n');
        };
        line(&self.headers, &widths, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for r in &self.rows {
            line(r, &widths, &mut out);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["n", "time"]);
        t.row(vec!["1280".into(), "1.5 ms".into()]);
        t.row(vec!["81920".into(), "12.0 ms".into()]);
        let s = t.render();
        assert!(s.contains("1280"));
        assert!(s.contains("81920"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_bad_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }
}
