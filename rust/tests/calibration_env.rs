//! Cost-profile files and the `HMATC_COSTS` environment fallback.
//!
//! This suite lives in its **own test binary** (like
//! `tests/codec_simd_dispatch.rs`): it mutates `HMATC_COSTS` with
//! `std::env::set_var`, and glibc's `setenv` racing a concurrent `getenv`
//! (thread-pool init reads `HMATC_THREADS`, executor selection reads
//! `HMATC_EXEC`) from another test thread is undefined behavior — isolation
//! by binary makes the mutation safe. Everything here runs in **one** test
//! function so even within this binary nothing runs concurrently with the
//! env mutation.

use hmatc::cluster::{BlockTree, ClusterTree, StdAdmissibility};
use hmatc::geometry::icosphere;
use hmatc::hmatrix::HMatrix;
use hmatc::kernelfn::{LaplaceSlp, MatrixGen};
use hmatc::lowrank::AcaOptions;
use hmatc::plan::costmodel::{CodecFamily, CostProfile, CostSource, KernelClass};
use hmatc::plan::PlannedOperator;
use hmatc::util::Rng;
use std::sync::Arc;

fn build_h(level: usize, eps: f64) -> HMatrix {
    let geom = icosphere(level);
    let gen = LaplaceSlp::new(&geom);
    let ct = Arc::new(ClusterTree::build(gen.points(), 16));
    let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
    HMatrix::build(&bt, &gen, &AcaOptions::with_eps(eps))
}

fn usable_profile(seed: u64) -> CostProfile {
    let mut rng = Rng::new(seed);
    CostProfile::from_coeffs(&[
        (KernelClass::MatBytes, 1e-10 * (1.0 + rng.uniform())),
        (KernelClass::DenseFlop, 3e-10 * (1.0 + rng.uniform())),
        (KernelClass::LowRankFlop, 7e-10 * (1.0 + rng.uniform())),
        (KernelClass::PanelVec, 2e-10 * (1.0 + rng.uniform())),
        (KernelClass::Decode(CodecFamily::Aflp, 4), 1.5e-9),
    ])
}

/// File-level round trip, hostile files, and the env fallback — one test on
/// purpose (see module docs).
#[test]
fn cost_profile_files_and_env_fallback() {
    let dir = std::env::temp_dir();
    let good = dir.join("hmatc_calib_test_good.json");
    let bad = dir.join("hmatc_calib_test_bad.json");
    let good_s = good.to_str().unwrap();
    let bad_s = bad.to_str().unwrap();
    let profile = usable_profile(7);
    profile.save(good_s).unwrap();
    std::fs::write(&bad, "{\"version\":1,\"coeffs\":{\"dense_f").unwrap();

    // round trip through the file, provenance recorded
    let loaded = CostProfile::load(good_s).unwrap();
    assert_eq!(loaded.to_json().to_string(), profile.to_json().to_string());
    assert_eq!(loaded.source, CostSource::Calibrated(good_s.to_string()));

    // hostile files error (no panic)
    assert!(CostProfile::load(bad_s).is_err());
    assert!(CostProfile::load("/nonexistent/hmatc_costs.json").is_err());
    assert!(CostProfile::parse("{\"version\":2,\"coeffs\":{}}").is_err());
    assert!(CostProfile::parse("{\"version\":1,\"coeffs\":{\"decode:zfp:4\":1e-9}}").is_err());
    assert!(CostProfile::parse("{\"version\":1,\"coeffs\":{\"dense_flop\":-2.0}}").is_err());

    // HMATC_COSTS at a bad/missing file: warn + static costs, never a panic
    let h = Arc::new(build_h(1, 1e-6));
    for p in [bad_s, "/nonexistent/hmatc_costs.json"] {
        std::env::set_var("HMATC_COSTS", p);
        let op = PlannedOperator::from_h(h.clone());
        assert_eq!(op.plan_stats().cost_source, CostSource::Static, "HMATC_COSTS={p}");
    }
    // a valid file re-balances and is reported as calibrated(<path>); the
    // per-path load cache must notice the changed variable
    std::env::set_var("HMATC_COSTS", good_s);
    let op = PlannedOperator::from_h(h.clone());
    assert_eq!(op.plan_stats().cost_source, CostSource::Calibrated(good_s.to_string()));
    // and a second operator under the same path (cached load) agrees
    let op2 = PlannedOperator::from_h(h.clone());
    assert_eq!(op2.plan_stats().cost_source, CostSource::Calibrated(good_s.to_string()));
    std::env::remove_var("HMATC_COSTS");

    let _ = std::fs::remove_file(&good);
    let _ = std::fs::remove_file(&bad);
}
