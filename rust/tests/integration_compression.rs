//! Compression behaviour end-to-end: error tracks ε (Fig. 9), AFLP vs FPX
//! trade-offs, VALR effect.

use hmatc::cluster::{BlockTree, ClusterTree, StdAdmissibility};
use hmatc::compress::{Codec, CompressionConfig};
use hmatc::geometry::icosphere;
use hmatc::hmatrix::norms::rel_spectral_error;
use hmatc::hmatrix::HMatrix;
use hmatc::kernelfn::{LaplaceSlp, MatrixGen};
use hmatc::lowrank::AcaOptions;
use hmatc::mvm::{mvm, MvmAlgorithm};
use std::sync::Arc;

fn build(level: usize, eps: f64) -> HMatrix {
    let geom = icosphere(level);
    let gen = LaplaceSlp::new(&geom);
    let ct = Arc::new(ClusterTree::build(gen.points(), 32));
    let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
    HMatrix::build(&bt, &gen, &AcaOptions::with_eps(eps))
}

/// Fig. 9: the error of the compressed matrix vs the uncompressed reference
/// follows the prescribed ε for both codecs.
#[test]
fn compression_error_tracks_eps() {
    for &eps in &[1e-4, 1e-6] {
        let h = build(2, eps);
        for codec in [Codec::Aflp, Codec::Fpx] {
            let mut hz = h.clone();
            hz.compress(&CompressionConfig { codec, eps, valr: true });
            let n = h.nrows();
            let err = rel_spectral_error(
                n,
                |x, y| mvm(1.0, &hz, x, y, MvmAlgorithm::Seq),
                |x, y| mvm(1.0, &h, x, y, MvmAlgorithm::Seq),
                25,
                99,
            );
            // compression error must stay in the ε neighbourhood — not orders
            // of magnitude above (Fig. 9 shows ≈ε for all formats)
            // error must stay in the ε neighbourhood (byte alignment often
            // makes the codecs considerably *more* accurate than ε, so only
            // the upper bound is sharp — Fig. 9 shows ≲ε for all formats)
            assert!(err < 50.0 * eps, "{codec:?} eps={eps}: err {err}");
            assert!(err > 0.0, "{codec:?} eps={eps}: compression was lossless?");
        }
    }
}

/// Fig. 10 (right): compression ratio decreases as ε gets finer.
#[test]
fn ratio_decreases_with_accuracy() {
    let mut ratios = Vec::new();
    for &eps in &[1e-2, 1e-5, 1e-9] {
        let h = build(2, eps);
        let before = h.byte_size() as f64;
        let mut hz = h;
        hz.compress(&CompressionConfig::aflp(eps));
        ratios.push(before / hz.byte_size() as f64);
    }
    assert!(ratios[0] > ratios[1] && ratios[1] > ratios[2], "ratios {ratios:?}");
}

/// AFLP yields better compression than FPX for the same ε (paper §4.2: the
/// exponent adaptivity pays off on low-rank vectors of similar magnitude).
#[test]
fn aflp_compresses_better_than_fpx() {
    let h = build(3, 1e-6);
    let mut ha = h.clone();
    let mut hf = h.clone();
    ha.compress(&CompressionConfig::aflp(1e-6));
    hf.compress(&CompressionConfig::fpx(1e-6));
    assert!(
        ha.byte_size() <= hf.byte_size(),
        "aflp {} !<= fpx {}",
        ha.byte_size(),
        hf.byte_size()
    );
}

/// VALR beats fixed-precision compression of the low-rank factors.
#[test]
fn valr_beats_fixed_precision() {
    let h = build(3, 1e-8);
    let mut hv = h.clone();
    let mut hfix = h.clone();
    hv.compress(&CompressionConfig { codec: Codec::Aflp, eps: 1e-8, valr: true });
    hfix.compress(&CompressionConfig { codec: Codec::Aflp, eps: 1e-8, valr: false });
    assert!(hv.byte_size() < hfix.byte_size(), "valr {} !< fixed {}", hv.byte_size(), hfix.byte_size());
}

/// Compressing twice is a no-op (idempotent).
#[test]
fn compress_idempotent() {
    let mut h = build(1, 1e-6);
    h.compress(&CompressionConfig::aflp(1e-6));
    let b1 = h.byte_size();
    h.compress(&CompressionConfig::aflp(1e-6));
    assert_eq!(h.byte_size(), b1);
}
