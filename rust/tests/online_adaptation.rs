//! Online-adaptation invariants: the serving-time calibrator only re-fits
//! cost coefficients and re-partitions task→shard packings, so products must
//! stay **bitwise identical** across every mid-stream re-fit and packing
//! swap — for all three formats (H/UH/H²), compressed and uncompressed,
//! forward + adjoint + multi-RHS, through the adaptive server and the
//! sharded scatter/gather tier. Plus: the drift trigger's hysteresis holds
//! on a live operator (alternating noisy timings never swap), extending the
//! synthetic-sample unit tests in `coordinator/adaptive.rs`.
//!
//! No test here touches process environment variables, so this binary is
//! safe to run threaded.

use hmatc::cluster::{BlockTree, ClusterTree, StdAdmissibility};
use hmatc::compress::{Codec, CompressionConfig};
use hmatc::coordinator::{BatchPolicy, MvmServer, OnlineCalibrator, OnlineConfig};
use hmatc::geometry::icosphere;
use hmatc::hmatrix::HMatrix;
use hmatc::kernelfn::{LaplaceSlp, MatrixGen};
use hmatc::la::DMatrix;
use hmatc::lowrank::AcaOptions;
use hmatc::plan::costmodel::{CostSource, Sample};
use hmatc::plan::{ExecutorKind, HOperator, PlannedOperator, TimingSink};
use hmatc::util::Rng;
use std::sync::Arc;

fn build_h(level: usize, eps: f64) -> HMatrix {
    let geom = icosphere(level);
    let gen = LaplaceSlp::new(&geom);
    let ct = Arc::new(ClusterTree::build(gen.points(), 16));
    let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
    HMatrix::build(&bt, &gen, &AcaOptions::with_eps(eps))
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(x.to_bits() == y.to_bits(), "{what}: row {i}: {x:e} vs {y:e}");
    }
}

/// The backends the online-adaptation matrix covers.
fn kinds() -> [ExecutorKind; 2] {
    [ExecutorKind::StaticLpt, ExecutorKind::WorkStealing]
}

/// Forward (twice, pinning arena/packing reuse), adjoint and multi-RHS.
fn run_all(op: &PlannedOperator, n: usize) -> (Vec<f64>, Vec<f64>, DMatrix, DMatrix) {
    let mut rng = Rng::new(727272);
    let x = rng.vector(n);
    let y0 = rng.vector(n);
    let xm = DMatrix::random(n, 3, &mut rng);
    let mut fwd = y0.clone();
    op.apply(0.75, &x, &mut fwd);
    op.apply(0.75, &x, &mut fwd);
    let mut adj = y0.clone();
    op.apply_adjoint(0.75, &x, &mut adj);
    let mut multi = DMatrix::zeros(n, 3);
    op.apply_multi(0.75, &xm, &mut multi);
    let mut multi_adj = DMatrix::zeros(n, 3);
    op.apply_multi_adjoint(0.75, &xm, &mut multi_adj);
    (fwd, adj, multi, multi_adj)
}

/// One real timed product through the whole-plan path, harvested the way
/// the adaptive server harvests: per-chunk samples plus the (predicted,
/// measured) makespan of the packing the batch ran on.
fn harvest(op: &PlannedOperator, nrhs: usize, seed: u64) -> (Vec<Sample>, f64, f64) {
    let sink = TimingSink::new(op.timing_slots());
    let n = op.ncols();
    let mut rng = Rng::new(seed);
    let x = DMatrix::random(n, nrhs, &mut rng);
    let mut y = DMatrix::zeros(op.nrows(), nrhs);
    op.apply_multi_timed(1.0, &x, &mut y, &sink);
    let mut samples = Vec::new();
    let (predicted, measured) = op.observe_multi(&sink, nrhs, &mut samples);
    (samples, predicted, measured)
}

/// Pin the invariant on one operator: baseline products, then live
/// observations driving the calibrator through its bootstrap fit AND
/// drift-armed re-fits (measured makespan inflated past the threshold),
/// re-checking bitwise equality after every swap opportunity.
fn check_online_swaps_invariant(op: Arc<PlannedOperator>, n: usize, tag: &str) {
    let base = run_all(&op, n);
    let cfg = OnlineConfig { min_samples: 1, hysteresis: 2, drift: 0.05, ..Default::default() };
    let cal = OnlineCalibrator::new(cfg, vec![op.clone()]);
    // bootstrap: no profile yet, predicted is the 0.0 sentinel
    let (s, p, m) = harvest(&op, 2, 31);
    cal.observe(&s, p, m);
    for round in 0..4u64 {
        let (s, p, m) = harvest(&op, 1 + (round as usize % 3), 32 + round);
        // inflate the measured makespan so the drift trigger itself fires
        cal.observe(&s, p, m.max(1e-9) * 10.0);
        let (f, a, mu, ma) = run_all(&op, n);
        assert_bits_eq(&f, &base.0, &format!("{tag} fwd round {round}"));
        assert_bits_eq(&a, &base.1, &format!("{tag} adj round {round}"));
        assert_bits_eq(mu.data(), base.2.data(), &format!("{tag} multi round {round}"));
        assert_bits_eq(ma.data(), base.3.data(), &format!("{tag} multi-adj round {round}"));
    }
    let st = cal.status();
    assert!(st.refits >= 1, "{tag}: bootstrap must attempt a fit ({st:?})");
    if st.swaps > 0 {
        assert_eq!(op.plan_stats().cost_source, CostSource::Online, "{tag}: swapped profile labels online");
    }
}

#[test]
fn online_swaps_are_bitwise_invariant_h() {
    let h0 = build_h(2, 1e-7);
    let n = h0.nrows();
    for compress in [false, true] {
        let mut h = h0.clone();
        if compress {
            h.compress(&CompressionConfig { codec: Codec::Aflp, eps: 1e-9, valr: true });
        }
        let h = Arc::new(h);
        for kind in kinds() {
            let op = Arc::new(PlannedOperator::from_h_with(h.clone(), kind));
            check_online_swaps_invariant(op, n, &format!("H compress={compress} [{kind}]"));
        }
    }
}

#[test]
fn online_swaps_are_bitwise_invariant_uh() {
    let h = build_h(2, 1e-7);
    let n = h.nrows();
    for compress in [false, true] {
        let mut uh = hmatc::uniform::build_from_h(&h, 1e-6, hmatc::uniform::CouplingKind::Combined);
        if compress {
            uh.compress(&CompressionConfig { codec: Codec::Fpx, eps: 1e-9, valr: true });
        }
        let uh = Arc::new(uh);
        for kind in kinds() {
            let op = Arc::new(PlannedOperator::from_uniform_with(uh.clone(), kind));
            check_online_swaps_invariant(op, n, &format!("UH compress={compress} [{kind}]"));
        }
    }
}

#[test]
fn online_swaps_are_bitwise_invariant_h2() {
    let h = build_h(2, 1e-7);
    let n = h.nrows();
    for compress in [false, true] {
        let mut h2 = hmatc::h2::build_from_h(&h, 1e-6);
        if compress {
            h2.compress(&CompressionConfig { codec: Codec::Aflp, eps: 1e-9, valr: true });
        }
        let h2 = Arc::new(h2);
        for kind in kinds() {
            let op = Arc::new(PlannedOperator::from_h2_with(h2.clone(), kind));
            check_online_swaps_invariant(op, n, &format!("H2 compress={compress} [{kind}]"));
        }
    }
}

/// Adaptive servers (unsharded and sharded) must serve the exact bits of a
/// static server over the same operator, with re-fits forced between
/// requests — for all three formats, compressed.
#[test]
fn adaptive_servers_match_static_bitwise_under_forced_swaps() {
    let h = build_h(2, 1e-7);
    let n = h.nrows();
    let cfg_z = CompressionConfig { codec: Codec::Aflp, eps: 1e-9, valr: true };
    let mut hz = h.clone();
    hz.compress(&cfg_z);
    let mut uh = hmatc::uniform::build_from_h(&h, 1e-6, hmatc::uniform::CouplingKind::Combined);
    uh.compress(&cfg_z);
    let mut h2 = hmatc::h2::build_from_h(&h, 1e-6);
    h2.compress(&cfg_z);
    let kind = ExecutorKind::StaticLpt;
    let ops: Vec<(&str, Arc<PlannedOperator>)> = vec![
        ("H", Arc::new(PlannedOperator::from_h_with(Arc::new(hz), kind))),
        ("UH", Arc::new(PlannedOperator::from_uniform_with(Arc::new(uh), kind))),
        ("H2", Arc::new(PlannedOperator::from_h2_with(Arc::new(h2), kind))),
    ];
    let mut rng = Rng::new(808);
    let xs: Vec<Vec<f64>> = (0..4).map(|_| rng.vector(n)).collect();
    let xp = DMatrix::random(n, 3, &mut rng);
    let policy = BatchPolicy::default();
    let cfg = OnlineConfig { min_samples: 1, ..Default::default() };
    for (name, op) in ops {
        // baseline: static server, sequential submits (singleton batches)
        let static_srv = MvmServer::start(op.clone(), policy);
        let want: Vec<Vec<f64>> = xs.iter().map(|x| static_srv.call(x.clone()).y).collect();
        let want_panel = static_srv.call_panel(xp.clone()).y;
        drop(static_srv);
        // adaptive, unsharded: force a re-fit + swap between every request
        let srv = MvmServer::start_adaptive(op.clone(), policy, cfg.clone());
        for (x, w) in xs.iter().zip(&want) {
            assert_bits_eq(&srv.call(x.clone()).y, w, &format!("{name} adaptive single"));
            srv.calibrator().expect("adaptive").force_refit();
        }
        assert_bits_eq(&srv.call_panel(xp.clone()).y, &want_panel, &format!("{name} adaptive panel"));
        drop(srv);
        // adaptive, sharded: same forced swaps through the scatter/gather tier
        let srv = MvmServer::start_sharded_adaptive(op.clone(), 2, kind, policy, cfg.clone())
            .expect("sharded adaptive server starts");
        for (x, w) in xs.iter().zip(&want) {
            assert_bits_eq(&srv.call(x.clone()).y, w, &format!("{name} sharded adaptive single"));
            srv.calibrator().expect("adaptive").force_refit();
        }
        assert_bits_eq(&srv.call_panel(xp.clone()).y, &want_panel, &format!("{name} sharded adaptive panel"));
    }
}

/// Hysteresis on a live operator: alternating noisy timings (every other
/// observation far over the drift threshold, the rest exactly on-model)
/// never reach the consecutive-streak requirement, so after the bootstrap
/// no further packing swap happens; sustained drift still re-fits.
#[test]
fn noisy_drift_never_swap_storms_on_live_operator() {
    let h = Arc::new(build_h(2, 1e-7));
    let op = Arc::new(PlannedOperator::from_h(h));
    let n = op.ncols();
    let base = run_all(&op, n);
    let cfg = OnlineConfig { min_samples: 1, hysteresis: 3, drift: 0.25, ..Default::default() };
    let cal = OnlineCalibrator::new(cfg, vec![op.clone()]);
    let (s, p, m) = harvest(&op, 1, 41);
    cal.observe(&s, p, m); // bootstrap fit fires on the first observation
    assert!(cal.status().refits >= 1, "bootstrap must attempt a fit");
    // the drift phases are only meaningful once a live profile is active
    // (real timings virtually always fit; degenerate clocks just skip them)
    if cal.status().swaps == 1 {
        for i in 0..40u64 {
            let (s, p, _) = harvest(&op, 1, 42 + i);
            // drive drift deterministically off the model's own prediction:
            // alternate between 2.0 (over threshold) and exactly 0.0
            let measured = if i % 2 == 0 { p * 3.0 } else { p };
            cal.observe(&s, p, measured);
        }
        assert_eq!(cal.status().swaps, 1, "alternating noise must not swap");
        // sustained drift (hysteresis consecutive observations) still re-fits
        let refits_before = cal.status().refits;
        for i in 0..3u64 {
            let (s, p, _) = harvest(&op, 1, 99 + i);
            cal.observe(&s, p, p.max(1e-9) * 3.0);
        }
        assert!(cal.status().refits > refits_before, "sustained drift must re-fit");
    }
    // and through it all, not one bit moved
    let now = run_all(&op, n);
    assert_bits_eq(&now.0, &base.0, "fwd after noise");
    assert_bits_eq(&now.1, &base.1, "adj after noise");
    assert_bits_eq(now.2.data(), base.2.data(), "multi after noise");
    assert_bits_eq(now.3.data(), base.3.data(), "multi-adj after noise");
}
