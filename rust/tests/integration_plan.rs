//! Execution-plan layer integration: plan executors must match the
//! sequential/recursive reference algorithms for all three formats ×
//! {uncompressed, AFLP+VALR, FPX+VALR, AFLP fixed-precision} × {forward,
//! adjoint, multi-RHS}, and the batching server must serve every format
//! end-to-end through the `HOperator` trait.

use hmatc::cluster::{BlockTree, ClusterTree, StdAdmissibility};
use hmatc::compress::{Codec, CompressionConfig};
use hmatc::coordinator::{BatchPolicy, MvmServer};
use hmatc::geometry::icosphere;
use hmatc::h2::H2Matrix;
use hmatc::hmatrix::HMatrix;
use hmatc::kernelfn::{LaplaceSlp, MatrixGen};
use hmatc::la::DMatrix;
use hmatc::lowrank::AcaOptions;
use hmatc::mvm::{h2_mvm, mvm, uniform_mvm, H2MvmAlgorithm, MvmAlgorithm, UniMvmAlgorithm};
use hmatc::plan::{Arena, H2Plan, HOperator, HPlan, PlannedOperator, UniPlan};
use hmatc::uniform::{CouplingKind, UniformHMatrix};
use hmatc::util::Rng;
use std::sync::Arc;
use std::time::Duration;

fn build_h(level: usize, eps: f64) -> HMatrix {
    let geom = icosphere(level);
    let gen = LaplaceSlp::new(&geom);
    let ct = Arc::new(ClusterTree::build(gen.points(), 16));
    let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
    HMatrix::build(&bt, &gen, &AcaOptions::with_eps(eps))
}

fn rel_l2(a: &[f64], b: &[f64]) -> f64 {
    let norm: f64 = b.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-300);
    let diff: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt();
    diff / norm
}

/// The compression sweep of the acceptance criteria. `None` = uncompressed.
fn configs() -> Vec<Option<CompressionConfig>> {
    vec![
        None,
        Some(CompressionConfig { codec: Codec::Aflp, eps: 1e-9, valr: true }),
        Some(CompressionConfig { codec: Codec::Fpx, eps: 1e-9, valr: true }),
        Some(CompressionConfig { codec: Codec::Aflp, eps: 1e-9, valr: false }),
    ]
}

#[test]
fn h_plan_matches_seq_all_configs() {
    let h0 = build_h(2, 1e-7); // n = 320
    let n = h0.nrows();
    let mut rng = Rng::new(901);
    let x = rng.vector(n);
    for (ci, cfg) in configs().iter().enumerate() {
        let mut h = h0.clone();
        if let Some(c) = cfg {
            h.compress(c);
        }
        // same data, same block kernels, different traversal → 1e-12 relative
        let mut y_ref = rng.vector(n);
        let y0 = y_ref.clone();
        mvm(1.5, &h, &x, &mut y_ref, MvmAlgorithm::Seq);
        let mut y = y0.clone();
        mvm(1.5, &h, &x, &mut y, MvmAlgorithm::Plan);
        assert!(rel_l2(&y, &y_ref) < 1e-12, "config {ci}: rel {}", rel_l2(&y, &y_ref));
    }
}

#[test]
fn uniform_plan_matches_row_wise_all_configs() {
    let h = build_h(2, 1e-7);
    for kind in [CouplingKind::Combined, CouplingKind::Separate] {
        let uh0 = hmatc::uniform::build_from_h(&h, 1e-7, kind);
        let n = uh0.nrows();
        let mut rng = Rng::new(902);
        let x = rng.vector(n);
        for (ci, cfg) in configs().iter().enumerate() {
            let mut uh = uh0.clone();
            if let Some(c) = cfg {
                uh.compress(c);
            }
            let mut y_ref = vec![0.25; n];
            uniform_mvm(2.0, &uh, &x, &mut y_ref, UniMvmAlgorithm::RowWise);
            let mut y = vec![0.25; n];
            uniform_mvm(2.0, &uh, &x, &mut y, UniMvmAlgorithm::Plan);
            assert!(rel_l2(&y, &y_ref) < 1e-12, "{kind:?} config {ci}: rel {}", rel_l2(&y, &y_ref));
        }
    }
}

#[test]
fn h2_plan_matches_row_wise_all_configs() {
    let h = build_h(2, 1e-7);
    let h20 = hmatc::h2::build_from_h(&h, 1e-7);
    let n = h20.nrows();
    let mut rng = Rng::new(903);
    let x = rng.vector(n);
    for (ci, cfg) in configs().iter().enumerate() {
        let mut h2 = h20.clone();
        if let Some(c) = cfg {
            h2.compress(c);
        }
        let mut y_ref = vec![0.0; n];
        h2_mvm(1.0, &h2, &x, &mut y_ref, H2MvmAlgorithm::RowWise);
        let mut y = vec![0.0; n];
        h2_mvm(1.0, &h2, &x, &mut y, H2MvmAlgorithm::Plan);
        assert!(rel_l2(&y, &y_ref) < 1e-12, "config {ci}: rel {}", rel_l2(&y, &y_ref));
    }
}

#[test]
fn h_plan_adjoint_matches_recursive_adjoint() {
    let h0 = build_h(2, 1e-7);
    let n = h0.nrows();
    let mut rng = Rng::new(904);
    let x = rng.vector(n);
    for (ci, cfg) in configs().iter().enumerate() {
        let mut h = h0.clone();
        if let Some(c) = cfg {
            h.compress(c);
        }
        let mut y_ref = vec![0.0; h.ncols()];
        hmatc::mvm::mvm_transposed(1.0, &h, &x, &mut y_ref);
        let plan = HPlan::build(&h);
        let mut arena = Arena::new();
        let mut y = vec![0.0; h.ncols()];
        plan.execute_adjoint(&h, 1.0, &x, &mut y, &mut arena);
        assert!(rel_l2(&y, &y_ref) < 1e-12, "config {ci}: rel {}", rel_l2(&y, &y_ref));
    }
}

#[test]
fn uniform_and_h2_plan_adjoint_match_dense_transpose() {
    let h = build_h(2, 1e-8);
    let uh = hmatc::uniform::build_from_h(&h, 1e-8, CouplingKind::Combined);
    let h2 = hmatc::h2::build_from_h(&h, 1e-8);
    let n = h.nrows();
    let mut rng = Rng::new(905);
    let x = rng.vector(n);

    let dt_u = uh.to_dense().transpose();
    let mut want_u = vec![0.0; n];
    hmatc::la::gemv(1.5, &dt_u, &x, &mut want_u);
    let plan_u = UniPlan::build(&uh);
    let mut arena = Arena::new();
    let mut y_u = vec![0.0; n];
    plan_u.execute_adjoint(&uh, 1.5, &x, &mut y_u, &mut arena);
    assert!(rel_l2(&y_u, &want_u) < 1e-10, "uniform adjoint rel {}", rel_l2(&y_u, &want_u));

    let dt_2 = h2.to_dense().transpose();
    let mut want_2 = vec![0.0; n];
    hmatc::la::gemv(1.5, &dt_2, &x, &mut want_2);
    let plan_2 = H2Plan::build(&h2);
    let mut y_2 = vec![0.0; n];
    plan_2.execute_adjoint(&h2, 1.5, &x, &mut y_2, &mut arena);
    assert!(rel_l2(&y_2, &want_2) < 1e-10, "h2 adjoint rel {}", rel_l2(&y_2, &want_2));
}

#[test]
fn compressed_adjoint_close_to_uncompressed() {
    let h = build_h(2, 1e-8);
    let uh = hmatc::uniform::build_from_h(&h, 1e-8, CouplingKind::Combined);
    let h2 = hmatc::h2::build_from_h(&h, 1e-8);
    let n = h.nrows();
    let mut rng = Rng::new(906);
    let x = rng.vector(n);
    let cfg = CompressionConfig::aflp(1e-10);

    let mut uhz = uh.clone();
    uhz.compress(&cfg);
    let mut y0 = vec![0.0; n];
    let mut y1 = vec![0.0; n];
    let mut arena = Arena::new();
    UniPlan::build(&uh).execute_adjoint(&uh, 1.0, &x, &mut y0, &mut arena);
    UniPlan::build(&uhz).execute_adjoint(&uhz, 1.0, &x, &mut y1, &mut arena);
    assert!(rel_l2(&y1, &y0) < 1e-6, "uniform compressed adjoint rel {}", rel_l2(&y1, &y0));

    let mut h2z = h2.clone();
    h2z.compress(&cfg);
    let mut z0 = vec![0.0; n];
    let mut z1 = vec![0.0; n];
    H2Plan::build(&h2).execute_adjoint(&h2, 1.0, &x, &mut z0, &mut arena);
    H2Plan::build(&h2z).execute_adjoint(&h2z, 1.0, &x, &mut z1, &mut arena);
    assert!(rel_l2(&z1, &z0) < 1e-6, "h2 compressed adjoint rel {}", rel_l2(&z1, &z0));
}

/// Acceptance sweep for the gemm-shaped batched schedules: `apply_multi` (and
/// the adjoint variant) for H, UH and H² must match repeated single-RHS
/// products to 1e-10, uncompressed and compressed, at several batch widths.
#[test]
fn gemm_plan_multi_rhs_matches_single_all_formats_and_configs() {
    let h0 = build_h(2, 1e-7);
    let uh0 = hmatc::uniform::build_from_h(&h0, 1e-7, CouplingKind::Combined);
    let h20 = hmatc::h2::build_from_h(&h0, 1e-7);
    let n = h0.nrows();
    let mut rng = Rng::new(907);
    for (ci, cfg) in configs().iter().enumerate() {
        let mut h = h0.clone();
        let mut uh = uh0.clone();
        let mut h2 = h20.clone();
        if let Some(c) = cfg {
            h.compress(c);
            uh.compress(c);
            h2.compress(c);
        }
        let ops: Vec<Box<dyn HOperator>> = vec![
            Box::new(PlannedOperator::from_h(Arc::new(h))),
            Box::new(PlannedOperator::from_uniform(Arc::new(uh))),
            Box::new(PlannedOperator::from_h2(Arc::new(h2))),
        ];
        // several widths: re-balanced LPT packings + panel scratch per width
        for &nrhs in &[1usize, 3, 5] {
            let x = DMatrix::random(n, nrhs, &mut rng);
            for op in &ops {
                let mut y = DMatrix::zeros(n, nrhs);
                op.apply_multi(1.25, &x, &mut y);
                for c in 0..nrhs {
                    let mut yc = vec![0.0; n];
                    op.apply(1.25, x.col(c), &mut yc);
                    let rel = rel_l2(y.col(c), &yc);
                    assert!(rel < 1e-10, "{} cfg {ci} b={nrhs} col {c}: rel {rel}", op.format_name());
                }
                let mut z = DMatrix::zeros(n, nrhs);
                op.apply_multi_adjoint(0.75, &x, &mut z);
                for c in 0..nrhs {
                    let mut zc = vec![0.0; n];
                    op.apply_adjoint(0.75, x.col(c), &mut zc);
                    let rel = rel_l2(z.col(c), &zc);
                    assert!(rel < 1e-10, "{} cfg {ci} b={nrhs} adjoint col {c}: rel {rel}", op.format_name());
                }
            }
        }
    }
}

/// The direct (un-planned) trait impls for UH and H² also batch through the
/// gemm-shaped plan pass — no per-column fallback anywhere.
#[test]
fn direct_operator_apply_multi_matches_single() {
    let h = build_h(2, 1e-7);
    let uh = hmatc::uniform::build_from_h(&h, 1e-7, CouplingKind::Combined);
    let h2 = hmatc::h2::build_from_h(&h, 1e-7);
    let n = h.nrows();
    let nrhs = 4;
    let mut rng = Rng::new(913);
    let x = DMatrix::random(n, nrhs, &mut rng);
    let ops: Vec<Box<dyn HOperator>> = vec![Box::new(h), Box::new(uh), Box::new(h2)];
    for op in &ops {
        let mut y = DMatrix::zeros(n, nrhs);
        op.apply_multi(1.5, &x, &mut y);
        for c in 0..nrhs {
            let mut yc = vec![0.0; n];
            op.apply(1.5, x.col(c), &mut yc);
            let rel = rel_l2(y.col(c), &yc);
            assert!(rel < 1e-10, "{} col {c}: rel {rel}", op.format_name());
        }
    }
}

/// Permutation folding: a `PlannedOperator::with_external_ordering` accepts
/// external-ordering vectors and must match the manual
/// to_internal → product → to_external chain (forward, adjoint, multi).
#[test]
fn external_ordering_fold_matches_manual_permutation() {
    let h = build_h(2, 1e-7);
    let n = h.nrows();
    let row_ct = h.bt.row_ct.clone();
    let col_ct = h.bt.col_ct.clone();
    let op = PlannedOperator::from_h(Arc::new(h.clone())).with_external_ordering();
    assert!(op.is_external_ordering());
    let mut rng = Rng::new(914);
    let x_ext = rng.vector(n);

    // forward, with a nonzero initial y (scatter must ADD, not overwrite)
    let mut y_ext = vec![0.25; n];
    op.apply(2.0, &x_ext, &mut y_ext);
    let xi = col_ct.to_internal(&x_ext);
    let mut yi = vec![0.0; n];
    mvm(2.0, &h, &xi, &mut yi, MvmAlgorithm::Seq);
    let want: Vec<f64> = row_ct.to_external(&yi).iter().map(|v| v + 0.25).collect();
    assert!(rel_l2(&y_ext, &want) < 1e-12, "forward rel {}", rel_l2(&y_ext, &want));

    // adjoint
    let mut z_ext = vec![0.0; n];
    op.apply_adjoint(1.0, &x_ext, &mut z_ext);
    let xri = row_ct.to_internal(&x_ext);
    let mut zi = vec![0.0; n];
    hmatc::mvm::mvm_transposed(1.0, &h, &xri, &mut zi);
    let wantz = col_ct.to_external(&zi);
    assert!(rel_l2(&z_ext, &wantz) < 1e-12, "adjoint rel {}", rel_l2(&z_ext, &wantz));

    // batched
    let nrhs = 3;
    let xm = DMatrix::random(n, nrhs, &mut rng);
    let mut ym = DMatrix::zeros(n, nrhs);
    op.apply_multi(1.0, &xm, &mut ym);
    for c in 0..nrhs {
        let xi = col_ct.to_internal(xm.col(c));
        let mut yi = vec![0.0; n];
        mvm(1.0, &h, &xi, &mut yi, MvmAlgorithm::Seq);
        let want = row_ct.to_external(&yi);
        assert!(rel_l2(ym.col(c), &want) < 1e-12, "multi col {c}");
    }

    // batched adjoint: the external-ordering fold swaps the permutation
    // roles (gather by the ROW tree, scatter by the COLUMN tree) — pin it
    // against the internal-ordering recursive adjoint per column, with a
    // nonzero initial Y (scatter must ADD, not overwrite)
    let mut zm = DMatrix::zeros(n, nrhs);
    for c in 0..nrhs {
        zm.col_mut(c).fill(0.5 + c as f64);
    }
    op.apply_multi_adjoint(1.5, &xm, &mut zm);
    for c in 0..nrhs {
        let xri = row_ct.to_internal(xm.col(c));
        let mut zi = vec![0.0; n];
        hmatc::mvm::mvm_transposed(1.5, &h, &xri, &mut zi);
        let want: Vec<f64> = col_ct.to_external(&zi).iter().map(|v| v + 0.5 + c as f64).collect();
        assert!(rel_l2(zm.col(c), &want) < 1e-12, "multi-adjoint col {c}: rel {}", rel_l2(zm.col(c), &want));
    }
}

#[test]
fn planned_operator_is_deterministic_across_calls() {
    // reused arena ⇒ repeated calls must be bitwise identical (collision-free
    // schedules have a fixed summation order)
    let h = build_h(1, 1e-8);
    let n = h.nrows();
    let op = PlannedOperator::from_h(Arc::new(h));
    let mut rng = Rng::new(908);
    let x = rng.vector(n);
    let mut y1 = vec![0.0; n];
    let mut y2 = vec![0.0; n];
    op.apply(1.0, &x, &mut y1);
    op.apply(1.0, &x, &mut y2);
    assert_eq!(y1, y2);
}

fn small_formats() -> (Arc<UniformHMatrix>, Arc<H2Matrix>, Arc<HMatrix>) {
    let h = build_h(1, 1e-6); // n = 80
    let uh = Arc::new(hmatc::uniform::build_from_h(&h, 1e-6, CouplingKind::Combined));
    let h2 = Arc::new(hmatc::h2::build_from_h(&h, 1e-6));
    (uh, h2, Arc::new(h))
}

#[test]
fn server_serves_uniform_matrix_end_to_end() {
    let (uh, _, _) = small_formats();
    let server = MvmServer::start(uh.clone(), BatchPolicy { max_batch: 4, linger: Duration::from_micros(200), ..BatchPolicy::default() });
    let mut rng = Rng::new(909);
    for _ in 0..4 {
        let x = rng.vector(uh.ncols());
        let resp = server.call(x.clone());
        let mut want = vec![0.0; uh.nrows()];
        uniform_mvm(1.0, &uh, &x, &mut want, UniMvmAlgorithm::RowWise);
        assert!(rel_l2(&resp.y, &want) < 1e-12);
    }
    assert_eq!(server.metrics.snapshot().requests, 4);
}

#[test]
fn server_serves_h2_matrix_end_to_end() {
    let (_, h2, _) = small_formats();
    let server = MvmServer::start(h2.clone(), BatchPolicy { max_batch: 4, linger: Duration::from_micros(200), ..BatchPolicy::default() });
    let mut rng = Rng::new(910);
    for _ in 0..4 {
        let x = rng.vector(h2.ncols());
        let resp = server.call(x.clone());
        let mut want = vec![0.0; h2.nrows()];
        h2_mvm(1.0, &h2, &x, &mut want, H2MvmAlgorithm::RowWise);
        assert!(rel_l2(&resp.y, &want) < 1e-12);
    }
}

#[test]
fn server_serves_planned_operators_all_formats() {
    let (uh, h2, h) = small_formats();
    let mut rng = Rng::new(911);
    let x = rng.vector(h.ncols());

    let mut want_h = vec![0.0; h.nrows()];
    mvm(1.0, &h, &x, &mut want_h, MvmAlgorithm::Seq);
    let mut want_u = vec![0.0; uh.nrows()];
    uniform_mvm(1.0, &uh, &x, &mut want_u, UniMvmAlgorithm::RowWise);
    let mut want_2 = vec![0.0; h2.nrows()];
    h2_mvm(1.0, &h2, &x, &mut want_2, H2MvmAlgorithm::RowWise);

    let cases: Vec<(Arc<dyn HOperator>, Vec<f64>)> = vec![
        (Arc::new(PlannedOperator::from_h(h)), want_h),
        (Arc::new(PlannedOperator::from_uniform(uh)), want_u),
        (Arc::new(PlannedOperator::from_h2(h2)), want_2),
    ];
    for (op, want) in cases {
        let name = op.format_name();
        let server = MvmServer::start(op, BatchPolicy::default());
        let resp = server.call(x.clone());
        assert!(rel_l2(&resp.y, &want) < 1e-12, "{name}: rel {}", rel_l2(&resp.y, &want));
    }
}
