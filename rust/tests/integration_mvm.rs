//! MVM algorithm equivalence across formats, codecs and thread counts, plus
//! the CG end-to-end solve.

use hmatc::cluster::{BlockTree, ClusterTree, StdAdmissibility};
use hmatc::compress::{Codec, CompressionConfig};
use hmatc::geometry::icosphere;
use hmatc::hmatrix::HMatrix;
use hmatc::kernelfn::{LaplaceSlp, MatrixGen};
use hmatc::lowrank::AcaOptions;
use hmatc::mvm::{h2_mvm, mvm, uniform_mvm, H2MvmAlgorithm, MvmAlgorithm, UniMvmAlgorithm};
use hmatc::solver::cg;
use hmatc::util::Rng;
use std::sync::Arc;

fn build(level: usize, eps: f64) -> HMatrix {
    let geom = icosphere(level);
    let gen = LaplaceSlp::new(&geom);
    let ct = Arc::new(ClusterTree::build(gen.points(), 32));
    let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
    HMatrix::build(&bt, &gen, &AcaOptions::with_eps(eps))
}

fn l2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
}

#[test]
fn h_algorithms_equivalent_on_larger_problem() {
    let h = build(3, 1e-6); // n = 1280
    let n = h.nrows();
    let mut rng = Rng::new(21);
    let x = rng.vector(n);
    let mut y_ref = vec![0.0; n];
    mvm(1.0, &h, &x, &mut y_ref, MvmAlgorithm::Seq);
    let norm: f64 = y_ref.iter().map(|v| v * v).sum::<f64>().sqrt();
    for algo in MvmAlgorithm::all() {
        let mut y = vec![0.0; n];
        mvm(1.0, &h, &x, &mut y, algo);
        assert!(l2(&y, &y_ref) < 1e-11 * norm, "{algo:?}");
    }
}

#[test]
fn compressed_algorithms_equivalent_both_codecs() {
    let h = build(2, 1e-6);
    let n = h.nrows();
    let mut rng = Rng::new(22);
    let x = rng.vector(n);
    let mut y_ref = vec![0.0; n];
    mvm(1.0, &h, &x, &mut y_ref, MvmAlgorithm::Seq);
    let norm: f64 = y_ref.iter().map(|v| v * v).sum::<f64>().sqrt();
    for codec in [Codec::Aflp, Codec::Fpx] {
        let mut hz = h.clone();
        hz.compress(&CompressionConfig { codec, eps: 1e-9, valr: true });
        for algo in MvmAlgorithm::all() {
            let mut y = vec![0.0; n];
            mvm(1.0, &hz, &x, &mut y, algo);
            assert!(l2(&y, &y_ref) < 1e-6 * norm, "{codec:?} {algo:?}: {}", l2(&y, &y_ref));
        }
    }
}

#[test]
fn uniform_and_h2_cross_algorithm_equivalence() {
    let h = build(2, 1e-7);
    let uh = hmatc::uniform::build_from_h(&h, 1e-7, hmatc::uniform::CouplingKind::Separate);
    let h2 = hmatc::h2::build_from_h(&h, 1e-7);
    let n = h.nrows();
    let mut rng = Rng::new(23);
    let x = rng.vector(n);
    let mut y_ref = vec![0.0; n];
    uniform_mvm(1.0, &uh, &x, &mut y_ref, UniMvmAlgorithm::RowWise);
    let norm: f64 = y_ref.iter().map(|v| v * v).sum::<f64>().sqrt();
    for algo in UniMvmAlgorithm::all() {
        let mut y = vec![0.0; n];
        uniform_mvm(1.0, &uh, &x, &mut y, algo);
        assert!(l2(&y, &y_ref) < 1e-10 * norm, "uh {algo:?}");
    }
    let mut y2_ref = vec![0.0; n];
    h2_mvm(1.0, &h2, &x, &mut y2_ref, H2MvmAlgorithm::RowWise);
    for algo in H2MvmAlgorithm::all() {
        let mut y = vec![0.0; n];
        h2_mvm(1.0, &h2, &x, &mut y, algo);
        assert!(l2(&y, &y2_ref) < 1e-10 * norm, "h2 {algo:?}");
    }
}

#[test]
fn alpha_scaling_and_accumulation() {
    let h = build(1, 1e-8);
    let n = h.nrows();
    let mut rng = Rng::new(24);
    let x = rng.vector(n);
    // y := 2Ax computed as two accumulations of alpha=1
    let mut y1 = vec![0.0; n];
    mvm(1.0, &h, &x, &mut y1, MvmAlgorithm::ClusterLists);
    mvm(1.0, &h, &x, &mut y1, MvmAlgorithm::ClusterLists);
    let mut y2 = vec![0.0; n];
    mvm(2.0, &h, &x, &mut y2, MvmAlgorithm::ClusterLists);
    assert!(l2(&y1, &y2) < 1e-12 * y2.iter().map(|v| v * v).sum::<f64>().sqrt());
}

/// End-to-end: BEM system solve with CG on the H-matrix operator, compressed
/// and uncompressed — solutions must agree; the SLP operator is SPD.
#[test]
fn cg_solve_end_to_end() {
    let h = build(2, 1e-8);
    let n = h.nrows();
    let mut rng = Rng::new(25);
    let b = rng.vector(n);

    let op = (n, |x: &[f64], y: &mut [f64]| mvm(1.0, &h, x, y, MvmAlgorithm::ClusterLists));
    let (x1, s1) = cg(&op, &b, 1e-10, 2000);
    assert!(s1.converged, "uncompressed CG residual {}", s1.residual);

    let mut hz = h.clone();
    hz.compress(&CompressionConfig::aflp(1e-8));
    let opz = (n, |x: &[f64], y: &mut [f64]| mvm(1.0, &hz, x, y, MvmAlgorithm::ClusterLists));
    let (x2, s2) = cg(&opz, &b, 1e-8, 2000);
    assert!(s2.converged, "compressed CG residual {}", s2.residual);

    let xnorm: f64 = x1.iter().map(|v| v * v).sum::<f64>().sqrt();
    assert!(l2(&x1, &x2) < 1e-3 * xnorm, "solutions diverge: {}", l2(&x1, &x2) / xnorm);
}

#[test]
fn single_threaded_pool_still_correct() {
    // HMATC_THREADS is read once per process; instead verify via a dedicated
    // small pool by running the sequential algorithm against parallel ones
    let h = build(2, 1e-6);
    let n = h.nrows();
    let mut rng = Rng::new(26);
    let x = rng.vector(n);
    let mut ys = vec![0.0; n];
    mvm(1.0, &h, &x, &mut ys, MvmAlgorithm::Seq);
    let mut yp = vec![0.0; n];
    mvm(1.0, &h, &x, &mut yp, MvmAlgorithm::ClusterLists);
    assert!(l2(&ys, &yp) < 1e-12 * ys.iter().map(|v| v * v).sum::<f64>().sqrt().max(1.0));
}
