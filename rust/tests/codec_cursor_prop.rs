//! Property test: a `DecodeCursor` — codec parameters resolved once, then
//! streamed in arbitrary chunk splits — must produce bit-identical output to
//! one-shot `decompress_range` calls, for FPX, AFLP and every per-column
//! VALR blob. Also pins cursor random access (`get`) against `Blob::get`
//! and the fused axpy against decode-then-`blas::axpy` (which the fused
//! kernels match bitwise by construction: identical per-element operations).

use hmatc::compress::{Blob, Codec, DecodeCursor, ZLowRankValr};
use hmatc::la::DMatrix;
use hmatc::lowrank::LowRank;
use hmatc::util::Rng;

/// Split `[0, n)` into random chunks (including empty-chunk probes) and
/// check the cursor's streamed output bit-for-bit against one-shot range
/// decodes of the same windows and of the whole blob.
fn check_random_splits(blob: &Blob, rng: &mut Rng, tag: &str) {
    let n = blob.n;
    let mut whole = vec![0.0f64; n];
    blob.decompress_range(0, n, &mut whole);

    for round in 0..8 {
        let mut cur = DecodeCursor::new(blob);
        let mut streamed = vec![0.0f64; n];
        let mut pos = 0usize;
        while pos < n {
            let len = match round % 3 {
                0 => 1 + rng.below(n - pos),                  // arbitrary
                1 => (1 + rng.below(7)).min(n - pos),         // tiny chunks
                _ => (32 + rng.below(97)).min(n - pos),       // kernel-sized
            };
            let before = cur.pos();
            cur.next_chunk(&mut streamed[pos..pos + len]);
            assert_eq!(cur.pos(), before + len, "{tag}: cursor position");

            // the same window through the one-shot path
            let mut window = vec![0.0f64; len];
            blob.decompress_range(pos, pos + len, &mut window);
            for (k, (a, b)) in streamed[pos..pos + len].iter().zip(&window).enumerate() {
                assert!(a.to_bits() == b.to_bits(), "{tag} round {round}: window {pos}..{} idx {}", pos + len, pos + k);
            }
            pos += len;
        }
        assert_eq!(cur.remaining(), 0, "{tag}: cursor exhausted");
        for (i, (a, b)) in streamed.iter().zip(&whole).enumerate() {
            assert!(a.to_bits() == b.to_bits(), "{tag} round {round}: idx {i}");
        }
    }

    // seek + re-stream from arbitrary offsets
    let mut cur = DecodeCursor::new(blob);
    for _ in 0..16 {
        if n == 0 {
            break;
        }
        let begin = rng.below(n);
        let len = 1 + rng.below(n - begin);
        cur.seek(begin);
        let mut out = vec![0.0f64; len];
        cur.next_chunk(&mut out);
        let mut want = vec![0.0f64; len];
        blob.decompress_range(begin, begin + len, &mut want);
        for (k, (a, b)) in out.iter().zip(&want).enumerate() {
            assert!(a.to_bits() == b.to_bits(), "{tag}: seek {begin} len {len} idx {}", begin + k);
        }
    }

    // random access with resolved params
    let cur = DecodeCursor::new(blob);
    for i in 0..n {
        assert_eq!(cur.get(i).to_bits(), blob.get(i).to_bits(), "{tag}: get({i})");
    }
}

fn random_data(n: usize, rng: &mut Rng) -> Vec<f64> {
    (0..n)
        .map(|i| {
            if i % 11 == 10 {
                0.0
            } else {
                rng.normal() * 10f64.powf(rng.range(-3.0, 3.0))
            }
        })
        .collect()
}

#[test]
fn cursor_matches_decompress_range_fpx_aflp() {
    let mut rng = Rng::new(31_000);
    let eps_list = [1e-2, 1e-5, 1e-8, 1e-11, 1e-15];
    for codec in [Codec::Aflp, Codec::Fpx] {
        for &eps in &eps_list {
            for _ in 0..4 {
                let n = 1 + rng.below(400);
                let data = random_data(n, &mut rng);
                let blob = Blob::compress(codec, &data, eps);
                check_random_splits(&blob, &mut rng, &format!("{codec:?} eps={eps} n={n}"));
            }
        }
    }
}

#[test]
fn cursor_matches_decompress_range_extreme_aflp() {
    // extreme dynamic range routes through the generic (wide) decode family
    let mut rng = Rng::new(32_000);
    let data: Vec<f64> = (0..137).map(|i| if i % 2 == 0 { 1e-220 * (i + 1) as f64 } else { 1e220 / (i + 1) as f64 }).collect();
    let blob = Blob::compress(Codec::Aflp, &data, 1e-4);
    check_random_splits(&blob, &mut rng, "aflp wide");
}

#[test]
fn cursor_matches_decompress_range_zero_blob() {
    let mut rng = Rng::new(33_000);
    let zeros = vec![0.0; 97];
    let blob = Blob::compress(Codec::Fpx, &zeros, 1e-6);
    check_random_splits(&blob, &mut rng, "zero");
}

#[test]
fn cursor_matches_decompress_range_valr_columns() {
    // VALR picks a different accuracy (and width) per column — every column
    // blob must stream identically through a cursor
    let mut rng = Rng::new(34_000);
    let (qu, _) = hmatc::la::qr_thin(&DMatrix::random(83, 9, &mut rng));
    let (qv, _) = hmatc::la::qr_thin(&DMatrix::random(71, 9, &mut rng));
    let mut v = qv;
    for i in 0..9 {
        let s = 0.2f64.powi(i as i32);
        for x in v.col_mut(i) {
            *x *= s;
        }
    }
    let lr = LowRank { u: qu, v };
    for codec in [Codec::Aflp, Codec::Fpx] {
        for &eps in &[1e-4, 1e-9, 1e-13] {
            let z = ZLowRankValr::compress_lowrank(&lr, codec, eps);
            for (i, col) in z.wcols.iter().chain(z.xcols.iter()).enumerate() {
                check_random_splits(col, &mut rng, &format!("valr {codec:?} eps={eps} col {i}"));
            }
        }
    }
}

#[test]
fn fused_axpy_equals_decode_then_axpy_bitwise() {
    let mut rng = Rng::new(35_000);
    for codec in [Codec::Aflp, Codec::Fpx] {
        for &eps in &[1e-3, 1e-9] {
            let n = 211;
            let data = random_data(n, &mut rng);
            let blob = Blob::compress(codec, &data, eps);
            let dec = blob.to_vec();
            let mut y1 = rng.vector(n);
            let mut y2 = y1.clone();
            hmatc::la::axpy(0.75, &dec, &mut y1);
            DecodeCursor::new(&blob).axpy(0.75, &mut y2);
            for (i, (a, b)) in y1.iter().zip(&y2).enumerate() {
                assert!(a.to_bits() == b.to_bits(), "{codec:?} eps={eps} idx {i}");
            }
        }
    }
}
