//! NUMA execution contracts (integration level): single-node fallback,
//! pin-failure degradation, the per-pool sample-floor fallback of
//! [`fit_pools`], profile round-trips with topology fingerprints, and the
//! shard→pool mapping invariants the pool-aware packers rely on.
//!
//! Everything here builds topologies **directly** ([`Topology::detect`] /
//! [`Topology::from_nodes`]) — no `std::env::set_var`, which would race
//! other tests in the same process.

use hmatc::cluster::{BlockTree, ClusterTree, StdAdmissibility};
use hmatc::geometry::icosphere;
use hmatc::hmatrix::HMatrix;
use hmatc::kernelfn::{LaplaceSlp, MatrixGen};
use hmatc::lowrank::AcaOptions;
use hmatc::par::topology::{pin_current_thread, MAX_CPU_ID};
use hmatc::par::{NodeInfo, Topology};
use hmatc::plan::costmodel::{
    fit_pools, pool_of_shard, CostProfile, KernelClass, Sample, TaskFeats, TopologyMeta, POOL_SAMPLE_FLOOR,
};
use hmatc::plan::{ExecutorKind, HOperator, PlannedOperator};
use hmatc::util::Rng;
use std::sync::Arc;

// ---------------------------------------------------------------------------
// single-node fallback
// ---------------------------------------------------------------------------

#[test]
fn disabled_numa_is_a_single_unpinnable_node() {
    let t = Topology::detect(false, true);
    assert_eq!(t.num_nodes(), 1);
    assert_eq!(t.cores_per_node(), 0);
    for k in 1..5 {
        for p in 0..k {
            let (node, cpus) = t.pool_placement(k, p);
            assert_eq!(node, Some(0), "k={k} p={p}");
            assert!(cpus.is_empty(), "fallback node must never yield pinnable cpus (k={k} p={p})");
        }
    }
    // the "don't pin" sentinel really does not pin
    assert!(!pin_current_thread(&[]));
}

#[test]
fn empty_node_list_falls_back_too() {
    let t = Topology::from_nodes(Vec::new(), true);
    assert_eq!(t.num_nodes(), 1);
    assert!(t.nodes()[0].cpus.is_empty());
    assert_eq!(t.node_mem(), vec![0]);
}

// ---------------------------------------------------------------------------
// pin-failure degradation
// ---------------------------------------------------------------------------

#[test]
fn failed_pin_degrades_without_breaking_products() {
    // cpu id 1023 does not exist on any sane CI box: the pin must report
    // failure (not panic) and the thread must keep computing correctly.
    if std::thread::available_parallelism().map_or(0, |n| n.get()) >= 512 {
        return; // machine big enough that the "bogus" cpu might be real
    }
    assert!(!pin_current_thread(&[MAX_CPU_ID]));

    // products on the sharded backend — whose workers attempt pinning at
    // startup — still match the unpinned lpt baseline bit for bit
    let geom = icosphere(2);
    let gen = LaplaceSlp::new(&geom);
    let ct = Arc::new(ClusterTree::build(gen.points(), 16));
    let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
    let h = Arc::new(HMatrix::build(&bt, &gen, &AcaOptions::with_eps(1e-7)));
    let n = h.nrows();
    let sharded = PlannedOperator::from_h_with(h.clone(), ExecutorKind::Sharded(3));
    let lpt = PlannedOperator::from_h_with(h, ExecutorKind::StaticLpt);
    let mut rng = Rng::new(7);
    let x = rng.vector(n);
    let (mut y1, mut y2) = (vec![0.0; n], vec![0.0; n]);
    sharded.apply(1.0, &x, &mut y1);
    lpt.apply(1.0, &x, &mut y2);
    for (i, (a, b)) in y1.iter().zip(&y2).enumerate() {
        assert!(a.to_bits() == b.to_bits(), "row {i}: {a:e} vs {b:e}");
    }
}

// ---------------------------------------------------------------------------
// per-pool fit: sample floor fallback
// ---------------------------------------------------------------------------

fn sample(pool: usize, amount: f64, secs: f64) -> Sample {
    let mut feats = TaskFeats::default();
    feats.add(KernelClass::DenseFlop, amount);
    Sample { feats, nrhs: 1, pool, secs }
}

#[test]
fn pool_below_sample_floor_falls_back_to_global() {
    // pool 0: plenty of samples at 2 s per unit; pool 1: a handful at 10 s
    // per unit — too few to earn an overlay
    let mut samples = Vec::new();
    for i in 0..POOL_SAMPLE_FLOOR + 16 {
        let a = 1.0 + (i % 7) as f64;
        samples.push(sample(0, a, 2.0 * a));
    }
    for i in 0..POOL_SAMPLE_FLOOR / 4 {
        let a = 1.0 + (i % 5) as f64;
        samples.push(sample(1, a, 10.0 * a));
    }
    let p = fit_pools(&samples, 2).unwrap();
    assert!(p.has_pool_coeffs());
    assert_eq!(p.pool_source_labels(), vec!["per-pool", "global"]);
    // pool 0 keeps its own (fast) rate; pool 1 uses the pooled global fit
    let c0 = p.pool_coeff(0, KernelClass::DenseFlop);
    let c1 = p.pool_coeff(1, KernelClass::DenseFlop);
    let g = p.cost(&sample(0, 1.0, 0.0).feats, 1);
    assert!((c0 - 2.0).abs() < 1e-6, "pool 0 overlay rate: {c0}");
    assert!((c1 - g).abs() < 1e-12, "pool 1 must fall back to the global coefficient");
}

#[test]
fn both_pools_above_floor_get_their_own_rates() {
    let mut samples = Vec::new();
    for i in 0..POOL_SAMPLE_FLOOR + 8 {
        let a = 1.0 + (i % 7) as f64;
        samples.push(sample(0, a, 2.0 * a));
        samples.push(sample(1, a, 6.0 * a));
    }
    let p = fit_pools(&samples, 2).unwrap();
    assert_eq!(p.pool_source_labels(), vec!["per-pool", "per-pool"]);
    assert!((p.pool_coeff(0, KernelClass::DenseFlop) - 2.0).abs() < 1e-6);
    assert!((p.pool_coeff(1, KernelClass::DenseFlop) - 6.0).abs() < 1e-6);
}

#[test]
fn single_pool_fit_has_no_pool_dimension() {
    let samples: Vec<Sample> = (0..8).map(|i| sample(0, 1.0 + i as f64, 3.0 * (1.0 + i as f64))).collect();
    let p = fit_pools(&samples, 1).unwrap();
    assert!(!p.has_pool_coeffs());
    assert!(p.pool_source_labels().is_empty());
}

// ---------------------------------------------------------------------------
// profile round-trip: topology fingerprint guards per-pool reuse
// ---------------------------------------------------------------------------

#[test]
fn mismatched_topology_drops_pool_overlays_on_load() {
    let mut samples = Vec::new();
    for i in 0..POOL_SAMPLE_FLOOR + 8 {
        let a = 1.0 + (i % 7) as f64;
        samples.push(sample(0, a, 2.0 * a));
        samples.push(sample(1, a, 6.0 * a));
    }
    let mut p = fit_pools(&samples, 2).unwrap();
    // a fingerprint no real machine running this test will match
    p.topology = Some(TopologyMeta { nodes: 99, cores_per_node: 7, pinned: true });
    let path = std::env::temp_dir().join(format!("hmatc-numa-prof-{}.json", std::process::id()));
    let path = path.to_str().unwrap().to_string();
    p.save(&path).unwrap();
    let loaded = CostProfile::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    // per-pool overlays calibrated elsewhere must not skew packing here
    assert!(!loaded.has_pool_coeffs(), "mismatched per-pool overlays must be dropped");
    // ... but the global fit survives
    assert!((loaded.pool_coeff(0, KernelClass::DenseFlop) - loaded.pool_coeff(1, KernelClass::DenseFlop)).abs() < 1e-12);
    assert!(loaded.is_usable());
}

#[test]
fn pool_overlays_without_fingerprint_are_dropped_on_load() {
    let mut samples = Vec::new();
    for i in 0..POOL_SAMPLE_FLOOR + 8 {
        let a = 1.0 + (i % 7) as f64;
        samples.push(sample(0, a, 2.0 * a));
        samples.push(sample(1, a, 6.0 * a));
    }
    let p = fit_pools(&samples, 2).unwrap();
    assert!(p.topology.is_none(), "fit_pools must not invent a fingerprint");
    let path = std::env::temp_dir().join(format!("hmatc-numa-nofp-{}.json", std::process::id()));
    let path = path.to_str().unwrap().to_string();
    p.save(&path).unwrap();
    let loaded = CostProfile::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert!(!loaded.has_pool_coeffs());
}

// ---------------------------------------------------------------------------
// shard→pool mapping and placement invariants
// ---------------------------------------------------------------------------

#[test]
fn pool_of_shard_partitions_shards_contiguously() {
    for nshards in 1..12usize {
        for npools in 1..6usize {
            let pools: Vec<usize> = (0..nshards).map(|s| pool_of_shard(s, nshards, npools)).collect();
            for (s, &p) in pools.iter().enumerate() {
                assert!(p < npools, "nshards={nshards} npools={npools}");
                // the inverse of the contiguous shard→pool dealing: shard s
                // must lie inside its pool's part_range slice
                let r = hmatc::plan::schedule::part_range(nshards, npools, p);
                assert!(r.contains(&s), "shard {s} outside pool {p} range {r:?} (nshards={nshards} npools={npools})");
            }
            // monotone non-decreasing along the level
            for w in pools.windows(2) {
                assert!(w[1] >= w[0], "non-monotone: {pools:?}");
            }
            // with at least as many shards as pools, every pool gets work
            // and shard 0 sits on pool 0
            if nshards >= npools {
                assert_eq!(pools[0], 0, "nshards={nshards} npools={npools}");
                for p in 0..npools {
                    assert!(pools.contains(&p), "pool {p} starved: {pools:?}");
                }
            }
        }
    }
}

#[test]
fn placement_slices_are_disjoint_within_a_node() {
    let t = Topology::from_nodes(
        vec![
            NodeInfo { id: 0, cpus: vec![0, 1, 2, 3], mem_bytes: 2 << 30 },
            NodeInfo { id: 1, cpus: vec![4, 5, 6, 7], mem_bytes: 1 << 30 },
        ],
        true,
    );
    for k in 1..=8 {
        let mut seen: Vec<Vec<usize>> = Vec::new();
        for p in 0..k {
            let (node, cpus) = t.pool_placement(k, p);
            let node = node.unwrap();
            assert!(!cpus.is_empty());
            // every cpu belongs to the claimed node
            let home = t.nodes().iter().find(|n| n.id == node).unwrap();
            assert!(cpus.iter().all(|c| home.cpus.contains(c)), "k={k} p={p}");
            seen.push(cpus);
        }
        // when no node is oversubscribed, slices never overlap
        if k <= 8 {
            let per_node_pools = (k + 1) / 2;
            if per_node_pools <= 4 {
                for i in 0..seen.len() {
                    for j in i + 1..seen.len() {
                        let overlap = seen[i].iter().any(|c| seen[j].contains(c));
                        let same_node = seen[i][0] / 4 == seen[j][0] / 4;
                        assert!(!overlap || !same_node, "k={k}: pools {i},{j} overlap: {seen:?}");
                    }
                }
            }
        }
    }
    assert_eq!(t.node_mem(), vec![2 << 30, 1 << 30]);
}
