//! Property-based tests (seeded PRNG sweeps — no proptest in the sandbox):
//! codec round-trip bounds, cluster/block tree invariants, MVM linearity.

use hmatc::cluster::{BlockTree, ClusterTree, StdAdmissibility};
use hmatc::compress::{max_rel_error, Blob, Codec};
use hmatc::geometry::{fibonacci_sphere, random_cube};
use hmatc::util::Rng;
use std::sync::Arc;

/// Codec round-trip: for ANY data distribution and ANY eps in range, the
/// per-value relative error stays ≤ eps (100 random cases per codec).
#[test]
fn prop_codec_roundtrip_error_bound() {
    let mut rng = Rng::new(777);
    for case in 0..100 {
        let n = 1 + rng.below(400);
        let scale = 10f64.powf(rng.range(-12.0, 12.0));
        let spread = 10f64.powf(rng.range(0.0, 6.0));
        let data: Vec<f64> = (0..n)
            .map(|_| {
                let v = rng.normal() * scale * spread.powf(rng.uniform());
                if rng.below(20) == 0 {
                    0.0
                } else {
                    v
                }
            })
            .collect();
        let eps = 10f64.powf(rng.range(-12.0, -1.0));
        for codec in [Codec::Aflp, Codec::Fpx] {
            let blob = Blob::compress(codec, &data, eps);
            let err = max_rel_error(&blob, &data);
            assert!(err <= eps, "case {case} {codec:?}: n={n} eps={eps} err={err}");
        }
    }
}

/// Random access equals bulk decode at arbitrary indices.
#[test]
fn prop_random_access_consistency() {
    let mut rng = Rng::new(778);
    for _ in 0..50 {
        let n = 1 + rng.below(1000);
        let data: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let eps = 10f64.powf(rng.range(-10.0, -2.0));
        for codec in [Codec::Aflp, Codec::Fpx] {
            let blob = Blob::compress(codec, &data, eps);
            let bulk = blob.to_vec();
            for _ in 0..20 {
                let i = rng.below(n);
                assert_eq!(blob.get(i), bulk[i]);
            }
        }
    }
}

/// Cluster tree invariants over random point clouds: permutation validity,
/// disjoint children covering the parent, leaf size bound.
#[test]
fn prop_cluster_tree_invariants() {
    let mut rng = Rng::new(779);
    for case in 0..30 {
        let n = 10 + rng.below(2000);
        let n_min = 1 + rng.below(100);
        let pts = if case % 2 == 0 { random_cube(n, &mut rng) } else { fibonacci_sphere(n) };
        let ct = ClusterTree::build(&pts, n_min);
        // permutation property
        let mut seen = vec![false; n];
        for &e in &ct.perm {
            assert!(!seen[e], "case {case}: duplicate perm entry");
            seen[e] = true;
        }
        // children partition parents
        for nd in &ct.nodes {
            if nd.is_leaf() {
                assert!(nd.size() <= n_min.max(1), "case {case}: leaf too big");
                continue;
            }
            let mut ranges: Vec<_> = nd.children.iter().map(|&c| ct.node(c).range()).collect();
            ranges.sort_by_key(|r| r.start);
            assert_eq!(ranges.first().unwrap().start, nd.begin);
            assert_eq!(ranges.last().unwrap().end, nd.end);
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start, "case {case}: gap/overlap");
            }
        }
    }
}

/// Block tree tiles the product index set exactly, for random geometries and
/// admissibility parameters.
#[test]
fn prop_block_tree_partition() {
    let mut rng = Rng::new(780);
    for case in 0..10 {
        let n = 50 + rng.below(400);
        let pts = random_cube(n, &mut rng);
        let n_min = 8 + rng.below(32);
        let eta = rng.range(0.5, 4.0);
        let ct = Arc::new(ClusterTree::build(&pts, n_min));
        let bt = BlockTree::build(&ct, &ct, &StdAdmissibility::new(eta));
        bt.validate_partition().unwrap_or_else(|e| panic!("case {case}: {e}"));
    }
}

/// MVM is linear: A(ax + by) = aAx + bAy for random H-matrices.
#[test]
fn prop_mvm_linearity() {
    use hmatc::hmatrix::HMatrix;
    use hmatc::kernelfn::{ExpCovariance, MatrixGen};
    use hmatc::lowrank::AcaOptions;
    use hmatc::mvm::{mvm, MvmAlgorithm};

    let mut rng = Rng::new(781);
    for _ in 0..5 {
        let n = 100 + rng.below(300);
        let pts = random_cube(n, &mut rng);
        let gen = ExpCovariance::new(pts, rng.range(0.1, 1.0));
        let ct = Arc::new(ClusterTree::build(gen.points(), 16));
        let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
        let h = HMatrix::build(&bt, &gen, &AcaOptions::with_eps(1e-8));

        let x1 = rng.vector(n);
        let x2 = rng.vector(n);
        let (a, b) = (rng.range(-2.0, 2.0), rng.range(-2.0, 2.0));
        let xc: Vec<f64> = x1.iter().zip(&x2).map(|(u, v)| a * u + b * v).collect();

        let mut y_combined = vec![0.0; n];
        mvm(1.0, &h, &xc, &mut y_combined, MvmAlgorithm::ClusterLists);
        let mut y_sep = vec![0.0; n];
        mvm(a, &h, &x1, &mut y_sep, MvmAlgorithm::ClusterLists);
        mvm(b, &h, &x2, &mut y_sep, MvmAlgorithm::ClusterLists);

        let norm: f64 = y_combined.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-30);
        let diff: f64 = y_combined.iter().zip(&y_sep).map(|(u, v)| (u - v) * (u - v)).sum::<f64>().sqrt();
        assert!(diff < 1e-10 * norm, "linearity violated: {diff} vs {norm}");
    }
}

/// Plan executors agree with the sequential/recursive references for random
/// geometries, formats, compression configs and alpha — forward, adjoint and
/// multi-RHS all through the same plan.
#[test]
fn prop_plan_matches_reference_all_formats() {
    use hmatc::compress::CompressionConfig;
    use hmatc::hmatrix::HMatrix;
    use hmatc::kernelfn::{ExpCovariance, MatrixGen};
    use hmatc::la::DMatrix;
    use hmatc::lowrank::AcaOptions;
    use hmatc::mvm::{h2_mvm, mvm, uniform_mvm, H2MvmAlgorithm, MvmAlgorithm, UniMvmAlgorithm};
    use hmatc::plan::{HOperator, PlannedOperator};
    use hmatc::uniform::CouplingKind;

    let mut rng = Rng::new(783);
    for case in 0..4 {
        let n = 80 + rng.below(200);
        let pts = random_cube(n, &mut rng);
        let gen = ExpCovariance::new(pts, rng.range(0.2, 1.0));
        let ct = Arc::new(ClusterTree::build(gen.points(), 8 + rng.below(24)));
        let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(rng.range(1.0, 3.0))));
        let h = HMatrix::build(&bt, &gen, &AcaOptions::with_eps(1e-9));
        let mut uh = hmatc::uniform::build_from_h(&h, 1e-9, CouplingKind::Combined);
        let mut h2 = hmatc::h2::build_from_h(&h, 1e-9);
        let mut hc = h.clone();
        if case % 2 == 1 {
            let codec = if case % 4 == 1 { Codec::Aflp } else { Codec::Fpx };
            let cfg = CompressionConfig { codec, eps: 1e-10, valr: case % 4 == 1 };
            hc.compress(&cfg);
            uh.compress(&cfg);
            h2.compress(&cfg);
        }
        let alpha = rng.range(-2.0, 2.0);
        let x = rng.vector(n);

        let rel = |a: &[f64], b: &[f64]| {
            let norm: f64 = b.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-30);
            a.iter().zip(b).map(|(p, q)| (p - q) * (p - q)).sum::<f64>().sqrt() / norm
        };

        // forward, all three formats
        let mut y_ref = vec![0.0; n];
        mvm(alpha, &hc, &x, &mut y_ref, MvmAlgorithm::Seq);
        let mut y = vec![0.0; n];
        mvm(alpha, &hc, &x, &mut y, MvmAlgorithm::Plan);
        assert!(rel(&y, &y_ref) < 1e-12, "case {case} H: {}", rel(&y, &y_ref));

        let mut yu_ref = vec![0.0; n];
        uniform_mvm(alpha, &uh, &x, &mut yu_ref, UniMvmAlgorithm::RowWise);
        let mut yu = vec![0.0; n];
        uniform_mvm(alpha, &uh, &x, &mut yu, UniMvmAlgorithm::Plan);
        assert!(rel(&yu, &yu_ref) < 1e-12, "case {case} UH: {}", rel(&yu, &yu_ref));

        let mut y2_ref = vec![0.0; n];
        h2_mvm(alpha, &h2, &x, &mut y2_ref, H2MvmAlgorithm::RowWise);
        let mut y2 = vec![0.0; n];
        h2_mvm(alpha, &h2, &x, &mut y2, H2MvmAlgorithm::Plan);
        assert!(rel(&y2, &y2_ref) < 1e-12, "case {case} H2: {}", rel(&y2, &y2_ref));

        // adjoint and multi-RHS through the planned operator (H format)
        let op = PlannedOperator::from_h(Arc::new(hc.clone()));
        let mut ya_ref = vec![0.0; n];
        hmatc::mvm::mvm_transposed(alpha, &hc, &x, &mut ya_ref);
        let mut ya = vec![0.0; n];
        op.apply_adjoint(alpha, &x, &mut ya);
        assert!(rel(&ya, &ya_ref) < 1e-12, "case {case} adjoint: {}", rel(&ya, &ya_ref));

        let xm = DMatrix::random(n, 3, &mut rng);
        let mut ym = DMatrix::zeros(n, 3);
        op.apply_multi(alpha, &xm, &mut ym);
        for c in 0..3 {
            let mut yc = vec![0.0; n];
            mvm(alpha, &hc, xm.col(c), &mut yc, MvmAlgorithm::Seq);
            assert!(rel(ym.col(c), &yc) < 1e-12, "case {case} multi col {c}");
        }
    }
}

/// Byte size monotonicity: coarser eps never needs more bytes.
#[test]
fn prop_bytes_monotone_in_eps() {
    let mut rng = Rng::new(782);
    for _ in 0..30 {
        let n = 64 + rng.below(512);
        let data: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let e1 = 10f64.powf(rng.range(-6.0, -1.0));
        let e2 = e1 * 10f64.powf(rng.range(-6.0, -1.0)); // strictly finer
        for codec in [Codec::Aflp, Codec::Fpx] {
            let b1 = Blob::compress(codec, &data, e1).byte_size();
            let b2 = Blob::compress(codec, &data, e2).byte_size();
            assert!(b1 <= b2, "{codec:?}: eps {e1} → {b1} bytes, eps {e2} → {b2} bytes");
        }
    }
}
