//! Executor-backend equivalence: the plan-execution backends (`lpt`,
//! `steal`, `sharded:K`) may map tasks to threads differently, but every
//! task writes only its own disjoint range in its own fixed internal order —
//! so forward, adjoint and multi-RHS products must be **bitwise identical**
//! across backends, for all three formats, compressed and uncompressed.
//! Plus a stress test of the stealing substrate itself: recursive spawns
//! racing a `StealSet` run under oversubscription, and zero-worker pools.

use hmatc::cluster::{BlockTree, ClusterTree, StdAdmissibility};
use hmatc::compress::{Codec, CompressionConfig};
use hmatc::geometry::icosphere;
use hmatc::hmatrix::HMatrix;
use hmatc::kernelfn::{LaplaceSlp, MatrixGen};
use hmatc::la::DMatrix;
use hmatc::lowrank::AcaOptions;
use hmatc::par::{Scope, StealSet, ThreadPool};
use hmatc::plan::{ExecutorKind, HOperator, PlannedOperator};
use hmatc::util::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

fn build_h(level: usize, eps: f64) -> HMatrix {
    let geom = icosphere(level);
    let gen = LaplaceSlp::new(&geom);
    let ct = Arc::new(ClusterTree::build(gen.points(), 16));
    let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
    HMatrix::build(&bt, &gen, &AcaOptions::with_eps(eps))
}

/// The backends under comparison; `sharded:3` deliberately does not divide
/// the shard counts evenly.
fn kinds() -> [ExecutorKind; 4] {
    [ExecutorKind::StaticLpt, ExecutorKind::WorkStealing, ExecutorKind::Sharded(2), ExecutorKind::Sharded(3)]
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(x.to_bits() == y.to_bits(), "{what}: row {i}: {x:e} vs {y:e}");
    }
}

/// Forward, adjoint and multi-RHS (both directions) for one operator per
/// backend; every output must match the `lpt` baseline bit for bit. Repeated
/// products through the same operator also pin the arena-reuse paths.
fn check_operator(ops: &[(ExecutorKind, PlannedOperator)], n: usize, tag: &str) {
    let mut rng = Rng::new(4242);
    let x = rng.vector(n);
    let y0 = rng.vector(n); // nonzero start: backends must accumulate equally
    let xm = DMatrix::random(n, 3, &mut rng);
    let alpha = 0.75;

    let run = |op: &PlannedOperator| {
        let mut fwd = y0.clone();
        op.apply(alpha, &x, &mut fwd);
        op.apply(alpha, &x, &mut fwd); // second product: reused arena/packings
        let mut adj = y0.clone();
        op.apply_adjoint(alpha, &x, &mut adj);
        let mut multi = DMatrix::zeros(n, 3);
        op.apply_multi(alpha, &xm, &mut multi);
        let mut multi_adj = DMatrix::zeros(n, 3);
        op.apply_multi_adjoint(alpha, &xm, &mut multi_adj);
        (fwd, adj, multi, multi_adj)
    };

    let (bf, ba, bm, bma) = run(&ops[0].1);
    for (kind, op) in &ops[1..] {
        assert_eq!(op.executor_name(), kind.to_string());
        let (f, a, m, ma) = run(op);
        assert_bits_eq(&f, &bf, &format!("{tag} fwd [{kind}]"));
        assert_bits_eq(&a, &ba, &format!("{tag} adj [{kind}]"));
        assert_bits_eq(m.data(), bm.data(), &format!("{tag} multi [{kind}]"));
        assert_bits_eq(ma.data(), bma.data(), &format!("{tag} multi-adj [{kind}]"));
    }
}

#[test]
fn h_outputs_bitwise_identical_across_executors() {
    let h0 = build_h(2, 1e-7);
    let n = h0.nrows();
    for compress in [false, true] {
        let mut h = h0.clone();
        if compress {
            h.compress(&CompressionConfig { codec: Codec::Aflp, eps: 1e-9, valr: true });
        }
        let h = Arc::new(h);
        let ops: Vec<(ExecutorKind, PlannedOperator)> =
            kinds().iter().map(|&k| (k, PlannedOperator::from_h_with(h.clone(), k))).collect();
        check_operator(&ops, n, &format!("H compress={compress}"));
    }
}

#[test]
fn uh_outputs_bitwise_identical_across_executors() {
    let h = build_h(2, 1e-7);
    let n = h.nrows();
    for compress in [false, true] {
        let mut uh = hmatc::uniform::build_from_h(&h, 1e-6, hmatc::uniform::CouplingKind::Combined);
        if compress {
            uh.compress(&CompressionConfig { codec: Codec::Fpx, eps: 1e-9, valr: true });
        }
        let uh = Arc::new(uh);
        let ops: Vec<(ExecutorKind, PlannedOperator)> =
            kinds().iter().map(|&k| (k, PlannedOperator::from_uniform_with(uh.clone(), k))).collect();
        check_operator(&ops, n, &format!("UH compress={compress}"));
    }
}

#[test]
fn h2_outputs_bitwise_identical_across_executors() {
    let h = build_h(2, 1e-7);
    let n = h.nrows();
    for compress in [false, true] {
        let mut h2 = hmatc::h2::build_from_h(&h, 1e-6);
        if compress {
            h2.compress(&CompressionConfig { codec: Codec::Aflp, eps: 1e-9, valr: true });
        }
        let h2 = Arc::new(h2);
        let ops: Vec<(ExecutorKind, PlannedOperator)> =
            kinds().iter().map(|&k| (k, PlannedOperator::from_h2_with(h2.clone(), k))).collect();
        check_operator(&ops, n, &format!("H2 compress={compress}"));
    }
}

#[test]
fn external_ordering_identical_across_executors() {
    // the permutation fold runs around the executor — must not disturb it
    let h = Arc::new(build_h(2, 1e-7));
    let n = h.nrows();
    let mut rng = Rng::new(99);
    let x = rng.vector(n);
    let mut base: Option<Vec<f64>> = None;
    for kind in kinds() {
        let op = PlannedOperator::from_h_with(h.clone(), kind).with_external_ordering();
        let mut y = vec![0.0; n];
        op.apply(1.0, &x, &mut y);
        match &base {
            None => base = Some(y),
            Some(b) => assert_bits_eq(&y, b, &format!("external [{kind}]")),
        }
    }
}

// ---------------------------------------------------------------------------
// pool stress: recursive spawns + steals under oversubscription
// ---------------------------------------------------------------------------

fn spawn_tree<'e>(s: &Scope<'e>, depth: usize, c: &'e AtomicUsize) {
    c.fetch_add(1, Ordering::Relaxed);
    if depth > 0 {
        s.spawn(move |s2| spawn_tree(s2, depth - 1, c));
        s.spawn(move |s2| spawn_tree(s2, depth - 1, c));
    }
}

#[test]
fn steals_survive_recursive_spawns_under_oversubscription() {
    // 1 worker, 12 stealing slots + a binary spawn tree sharing the pool:
    // every queued closure and every seeded item must still run exactly once
    let pool = ThreadPool::new(1);
    let tree_count = AtomicUsize::new(0);
    let items = 300usize;
    let hits: Vec<AtomicUsize> = (0..items).map(|_| AtomicUsize::new(0)).collect();
    let mut set = StealSet::new();
    let set_ref = &mut set;
    let (pool_ref, hits_ref) = (&pool, &hits);
    pool.scope(|s| {
        s.spawn(|s2| spawn_tree(s2, 7, &tree_count));
        // StealSet::run opens a *nested* scope on the same pool from inside
        // a running task; help-first waiting makes this safe on any worker
        // count, including this oversubscribed 1-worker pool
        s.spawn(move |_| {
            set_ref.run(pool_ref, 12, items, |_slot, item| {
                hits_ref[item].fetch_add(1, Ordering::Relaxed);
                if item % 97 == 0 {
                    std::thread::yield_now(); // jitter → force real steals
                }
            });
        });
    });
    assert_eq!(tree_count.load(Ordering::Relaxed), (1 << 8) - 1);
    assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
}

#[test]
fn zero_worker_pool_still_progresses_with_steals() {
    let pool = ThreadPool::new(0);
    let count = AtomicUsize::new(0);
    let mut set = StealSet::new();
    for round in 1..5usize {
        set.run(&pool, 8, round * 11, |_s, _i| {
            count.fetch_add(1, Ordering::Relaxed);
        });
    }
    assert_eq!(count.load(Ordering::Relaxed), 11 + 22 + 33 + 44);
}

#[test]
fn sharded_executor_survives_oversubscription() {
    // more sub-pools than cores and more shards than slots: still every
    // product correct (equivalence already checked above; this pins k ≫ cores)
    let h = Arc::new(build_h(2, 1e-7));
    let n = h.nrows();
    let op = PlannedOperator::from_h_with(h.clone(), ExecutorKind::Sharded(7));
    let base = PlannedOperator::from_h_with(h, ExecutorKind::StaticLpt);
    let mut rng = Rng::new(5);
    let x = rng.vector(n);
    let (mut y1, mut y2) = (vec![0.0; n], vec![0.0; n]);
    op.apply(1.0, &x, &mut y1);
    base.apply(1.0, &x, &mut y2);
    assert_bits_eq(&y1, &y2, "sharded:7 vs lpt");
}
