//! Storage-tier roundtrip: `pack` → `MappedStore::open` → `attach_*` must
//! serve **bitwise identical** forward, adjoint and multi-RHS products to
//! the in-memory operator, for all three formats, compressed and
//! uncompressed, on every plan-execution backend — the mapping changes only
//! where the payload bytes live, never a single output bit. Plus hostile
//! pack files (truncated, corrupted, wrong magic, mismatched operator) and
//! the decode-once hot cache under an eviction-forcing tiny budget.

use hmatc::cluster::{BlockTree, ClusterTree, StdAdmissibility};
use hmatc::compress::{Codec, CompressionConfig};
use hmatc::geometry::icosphere;
use hmatc::hmatrix::HMatrix;
use hmatc::kernelfn::{LaplaceSlp, MatrixGen};
use hmatc::la::DMatrix;
use hmatc::lowrank::AcaOptions;
use hmatc::plan::{ExecutorKind, PlannedOperator};
use hmatc::store::{self, HotCache, MappedStore};
use hmatc::util::Rng;
use std::sync::Arc;

fn build_h(level: usize, eps: f64) -> HMatrix {
    let geom = icosphere(level);
    let gen = LaplaceSlp::new(&geom);
    let ct = Arc::new(ClusterTree::build(gen.points(), 16));
    let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
    HMatrix::build(&bt, &gen, &AcaOptions::with_eps(eps))
}

/// Unique temp pack path per test (tests run in parallel in one process).
fn tmp_path(tag: &str) -> String {
    let p = std::env::temp_dir().join(format!("hmatc_store_rt_{}_{tag}.hmpk", std::process::id()));
    p.to_str().unwrap().to_string()
}

fn kinds() -> [ExecutorKind; 3] {
    [ExecutorKind::StaticLpt, ExecutorKind::WorkStealing, ExecutorKind::Sharded(2)]
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(x.to_bits() == y.to_bits(), "{what}: row {i}: {x:e} vs {y:e}");
    }
}

/// Forward (twice — pins arena/cache reuse), adjoint and multi-RHS both
/// directions, from a fixed seed.
fn products(op: &PlannedOperator, n: usize) -> (Vec<f64>, Vec<f64>, DMatrix, DMatrix) {
    let mut rng = Rng::new(4242);
    let x = rng.vector(n);
    let y0 = rng.vector(n);
    let xm = DMatrix::random(n, 3, &mut rng);
    let alpha = 0.75;
    let mut fwd = y0.clone();
    op.apply(alpha, &x, &mut fwd);
    op.apply(alpha, &x, &mut fwd);
    let mut adj = y0;
    op.apply_adjoint(alpha, &x, &mut adj);
    let mut multi = DMatrix::zeros(n, 3);
    op.apply_multi(alpha, &xm, &mut multi);
    let mut multi_adj = DMatrix::zeros(n, 3);
    op.apply_multi_adjoint(alpha, &xm, &mut multi_adj);
    (fwd, adj, multi, multi_adj)
}

fn compare(mem: &PlannedOperator, mapped: &PlannedOperator, n: usize, tag: &str) {
    let (bf, ba, bm, bma) = products(mem, n);
    let (f, a, m, ma) = products(mapped, n);
    assert_bits_eq(&f, &bf, &format!("{tag} fwd"));
    assert_bits_eq(&a, &ba, &format!("{tag} adj"));
    assert_bits_eq(m.data(), bm.data(), &format!("{tag} multi"));
    assert_bits_eq(ma.data(), bma.data(), &format!("{tag} multi-adj"));
}

#[test]
fn h_mmap_roundtrip_bitwise() {
    let h0 = build_h(2, 1e-7);
    let n = h0.nrows();
    for compress in [false, true] {
        let mut h = h0.clone();
        if compress {
            h.compress(&CompressionConfig { codec: Codec::Aflp, eps: 1e-9, valr: true });
        }
        let path = tmp_path(&format!("h{}", compress as u8));
        let sum = store::pack_h(&h, &path).unwrap();
        assert_eq!(sum.extents > 0, compress, "payload extents iff compressed");
        let mstore = MappedStore::open(&path).unwrap();
        let mut hm = h.clone();
        store::attach_h(&mut hm, &mstore).unwrap();
        if compress {
            let r = store::residency_h(&hm, None);
            assert!(r.mapped_bytes > 0, "attached operator must be mapped");
            assert_eq!(r.anon_bytes, 0, "attach must re-point every blob");
        }
        let mem = PlannedOperator::from_h_with(Arc::new(h), ExecutorKind::StaticLpt);
        let hm = Arc::new(hm);
        for kind in kinds() {
            let mapped = PlannedOperator::from_h_with(hm.clone(), kind);
            compare(&mem, &mapped, n, &format!("H compress={compress} [{kind}]"));
        }
        drop(mstore); // operators pin the segment Arc; store handle may go first
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn uh_mmap_roundtrip_bitwise() {
    let h = build_h(2, 1e-7);
    let n = h.nrows();
    for compress in [false, true] {
        let mut uh = hmatc::uniform::build_from_h(&h, 1e-6, hmatc::uniform::CouplingKind::Combined);
        if compress {
            uh.compress(&CompressionConfig { codec: Codec::Fpx, eps: 1e-9, valr: true });
        }
        let path = tmp_path(&format!("uh{}", compress as u8));
        let sum = store::pack_uh(&uh, &path).unwrap();
        assert_eq!(sum.extents > 0, compress);
        let mstore = MappedStore::open(&path).unwrap();
        let mut um = uh.clone();
        store::attach_uh(&mut um, &mstore).unwrap();
        if compress {
            assert!(store::residency_uh(&um, None).mapped_bytes > 0);
        }
        let mem = PlannedOperator::from_uniform_with(Arc::new(uh), ExecutorKind::StaticLpt);
        let um = Arc::new(um);
        for kind in kinds() {
            let mapped = PlannedOperator::from_uniform_with(um.clone(), kind);
            compare(&mem, &mapped, n, &format!("UH compress={compress} [{kind}]"));
        }
        drop(mstore);
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn h2_mmap_roundtrip_bitwise() {
    let h = build_h(2, 1e-7);
    let n = h.nrows();
    for compress in [false, true] {
        let mut h2 = hmatc::h2::build_from_h(&h, 1e-6);
        if compress {
            h2.compress(&CompressionConfig { codec: Codec::Aflp, eps: 1e-9, valr: true });
        }
        let path = tmp_path(&format!("h2_{}", compress as u8));
        let sum = store::pack_h2(&h2, &path).unwrap();
        assert_eq!(sum.extents > 0, compress);
        let mstore = MappedStore::open(&path).unwrap();
        let mut m2 = h2.clone();
        store::attach_h2(&mut m2, &mstore).unwrap();
        if compress {
            assert!(store::residency_h2(&m2, None).mapped_bytes > 0);
        }
        let mem = PlannedOperator::from_h2_with(Arc::new(h2), ExecutorKind::StaticLpt);
        let m2 = Arc::new(m2);
        for kind in kinds() {
            let mapped = PlannedOperator::from_h2_with(m2.clone(), kind);
            compare(&mem, &mapped, n, &format!("H2 compress={compress} [{kind}]"));
        }
        drop(mstore);
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn hostile_pack_files_rejected() {
    let mut h = build_h(1, 1e-6);
    h.compress(&CompressionConfig { codec: Codec::Aflp, eps: 1e-8, valr: true });
    let path = tmp_path("hostile_good");
    let sum = store::pack_h(&h, &path).unwrap();
    assert!(sum.extents > 0);
    let bytes = std::fs::read(&path).unwrap();

    let reject = |tag: &str, data: &[u8]| {
        let p = tmp_path(tag);
        std::fs::write(&p, data).unwrap();
        assert!(MappedStore::open(&p).is_err(), "{tag}: must be rejected");
        std::fs::remove_file(&p).ok();
    };
    reject("hostile_trunc", &bytes[..bytes.len() - 1]);
    reject("hostile_short", &bytes[..10]);
    reject("hostile_empty", &[]);
    let mut bad = bytes.clone();
    bad[0] ^= 0xff; // magic
    reject("hostile_magic", &bad);
    let mut bad = bytes.clone();
    bad[4] ^= 0xff; // version
    reject("hostile_version", &bad);
    let mut bad = bytes.clone();
    *bad.last_mut().unwrap() ^= 0xff; // payload bit flip → extent checksum
    reject("hostile_payload", &bad);
    let mut bad = bytes.clone();
    bad[24] ^= 0xff; // first extent descriptor → header checksum
    reject("hostile_header", &bad);

    // a valid store must still refuse an operator with a different blob set
    let mstore = MappedStore::open(&path).unwrap();
    let mut other = build_h(1, 1e-6); // uncompressed: zero blobs
    assert!(store::attach_h(&mut other, &mstore).is_err(), "mismatched attach must fail");
    drop(mstore);
    std::fs::remove_file(&path).ok();
}

#[test]
fn tiny_hot_cache_eviction_stays_bitwise() {
    let mut h = build_h(2, 1e-7);
    h.compress(&CompressionConfig { codec: Codec::Aflp, eps: 1e-9, valr: true });
    let n = h.nrows();
    let path = tmp_path("hot");
    store::pack_h(&h, &path).unwrap();
    let mstore = MappedStore::open(&path).unwrap();
    let mut hm = h.clone();
    store::attach_h(&mut hm, &mstore).unwrap();
    let mem = PlannedOperator::from_h_with(Arc::new(h), ExecutorKind::StaticLpt);
    let mapped = PlannedOperator::from_h_with(Arc::new(hm), ExecutorKind::WorkStealing);

    // tiny budget: 512 decoded values — constant eviction churn, larger
    // panels bypass the cache entirely; outputs must not move a bit
    let tiny = HotCache::new(4096);
    mapped.set_hot_cache(Some(tiny.clone()));
    for _ in 0..3 {
        compare(&mem, &mapped, n, "hot tiny");
    }
    let (_, resident, _, misses) = tiny.stats();
    assert!(resident <= 4096, "budget violated: {resident}");
    assert!(misses > 0, "a 4 KB cache cannot hold a whole operator");

    // roomy budget: repeated products must actually hit, still bitwise
    let roomy = HotCache::new(64 << 20);
    mapped.set_hot_cache(Some(roomy.clone()));
    for _ in 0..2 {
        compare(&mem, &mapped, n, "hot roomy");
    }
    let (hits, _) = roomy.counters();
    assert!(hits > 0, "repeated products through a roomy cache must hit");

    mapped.set_hot_cache(None);
    compare(&mem, &mapped, n, "hot disabled again");
    drop(mstore);
    std::fs::remove_file(&path).ok();
}
