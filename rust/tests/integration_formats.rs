//! Cross-format consistency: H vs UH vs H² represent the same operator, with
//! the storage ordering the paper reports (Fig. 1).

use hmatc::cluster::{BlockTree, ClusterTree, StdAdmissibility};
use hmatc::geometry::icosphere;
use hmatc::h2::build_from_h as build_h2;
use hmatc::hmatrix::HMatrix;
use hmatc::kernelfn::{LaplaceSlp, MatrixGen};
use hmatc::lowrank::AcaOptions;
use hmatc::mvm::{h2_mvm, mvm, uniform_mvm, H2MvmAlgorithm, MvmAlgorithm, UniMvmAlgorithm};
use hmatc::uniform::{build_from_h as build_uh, CouplingKind};
use hmatc::util::Rng;
use std::sync::Arc;

struct AllFormats {
    h: HMatrix,
    uh: hmatc::uniform::UniformHMatrix,
    h2: hmatc::h2::H2Matrix,
}

fn build_all(level: usize, eps: f64) -> AllFormats {
    let geom = icosphere(level);
    let gen = LaplaceSlp::new(&geom);
    let ct = Arc::new(ClusterTree::build(gen.points(), 32));
    let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
    let h = HMatrix::build(&bt, &gen, &AcaOptions::with_eps(eps));
    let uh = build_uh(&h, eps, CouplingKind::Combined);
    let h2 = build_h2(&h, eps);
    AllFormats { h, uh, h2 }
}

#[test]
fn formats_agree_via_mvm() {
    let f = build_all(2, 1e-6);
    let n = f.h.nrows();
    let mut rng = Rng::new(7);
    let x = rng.vector(n);
    let mut yh = vec![0.0; n];
    let mut yu = vec![0.0; n];
    let mut y2 = vec![0.0; n];
    mvm(1.0, &f.h, &x, &mut yh, MvmAlgorithm::Seq);
    uniform_mvm(1.0, &f.uh, &x, &mut yu, UniMvmAlgorithm::RowWise);
    h2_mvm(1.0, &f.h2, &x, &mut y2, H2MvmAlgorithm::RowWise);
    let ynorm: f64 = yh.iter().map(|v| v * v).sum::<f64>().sqrt();
    let du: f64 = yh.iter().zip(&yu).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
    let d2: f64 = yh.iter().zip(&y2).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
    assert!(du < 1e-4 * ynorm, "UH deviates: {du} / {ynorm}");
    assert!(d2 < 1e-4 * ynorm, "H2 deviates: {d2} / {ynorm}");
}

#[test]
fn coupling_storage_ordering() {
    // the *matrix data* (couplings) of UH/H² is much smaller than H's
    // low-rank factors — this is §2.3/2.4's storage argument.
    let f = build_all(3, 1e-4);
    let h_lr_bytes = f.h.stats().lowrank_bytes;
    let uh_coupling = f.uh.stats().coupling_bytes;
    let h2_coupling = f.h2.stats().coupling_bytes;
    assert!(uh_coupling < h_lr_bytes, "uh coupling {uh_coupling} !< h lowrank {h_lr_bytes}");
    assert!(h2_coupling < h_lr_bytes);
}

#[test]
fn h2_basis_smaller_than_uh_basis() {
    // nested bases beat shared bases in storage for growing n (Fig. 1)
    let f = build_all(3, 1e-4);
    let uh_basis = f.uh.stats().basis_bytes;
    let h2_basis = f.h2.stats().basis_bytes;
    assert!(h2_basis < uh_basis, "h2 basis {h2_basis} !< uh basis {uh_basis}");
}

#[test]
fn all_formats_compress_and_stay_consistent() {
    let mut f = build_all(2, 1e-5);
    let n = f.h.nrows();
    let mut rng = Rng::new(9);
    let x = rng.vector(n);
    let mut y_ref = vec![0.0; n];
    mvm(1.0, &f.h, &x, &mut y_ref, MvmAlgorithm::Seq);
    let ynorm: f64 = y_ref.iter().map(|v| v * v).sum::<f64>().sqrt();

    let cfg = hmatc::compress::CompressionConfig::aflp(1e-5);
    f.h.compress(&cfg);
    f.uh.compress(&cfg);
    f.h2.compress(&cfg);

    let mut yh = vec![0.0; n];
    let mut yu = vec![0.0; n];
    let mut y2 = vec![0.0; n];
    mvm(1.0, &f.h, &x, &mut yh, MvmAlgorithm::ClusterLists);
    uniform_mvm(1.0, &f.uh, &x, &mut yu, UniMvmAlgorithm::RowWise);
    h2_mvm(1.0, &f.h2, &x, &mut y2, H2MvmAlgorithm::RowWise);
    for (name, y) in [("h", &yh), ("uh", &yu), ("h2", &y2)] {
        let d: f64 = y.iter().zip(&y_ref).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
        assert!(d < 1e-3 * ynorm, "{name}: {d} vs {ynorm}");
    }
}

#[test]
fn compression_ratio_ordering_matches_paper() {
    // Fig. 10: H compresses best, H² least (VALR applies to ever less data)
    let mut f = build_all(3, 1e-6);
    let h0 = f.h.byte_size() as f64;
    let u0 = f.uh.byte_size() as f64;
    let t0 = f.h2.byte_size() as f64;
    let cfg = hmatc::compress::CompressionConfig::aflp(1e-6);
    f.h.compress(&cfg);
    f.uh.compress(&cfg);
    f.h2.compress(&cfg);
    let rh = h0 / f.h.byte_size() as f64;
    let ru = u0 / f.uh.byte_size() as f64;
    let r2 = t0 / f.h2.byte_size() as f64;
    assert!(rh > 1.5, "H ratio {rh}");
    assert!(ru > 1.2, "UH ratio {ru}");
    assert!(r2 > 1.0, "H2 ratio {r2}");
    assert!(rh >= r2 * 0.95, "H ({rh}) should compress at least as well as H2 ({r2})");
}
