//! PJRT runtime integration: these tests require `make artifacts` to have
//! produced `artifacts/*.hlo.txt`; they are skipped (pass trivially with a
//! notice) when artifacts are absent so `cargo test` works pre-AOT.

#![cfg(feature = "pjrt")]

use hmatc::cluster::{BlockTree, ClusterTree, StdAdmissibility};
use hmatc::geometry::icosphere;
use hmatc::hmatrix::HMatrix;
use hmatc::kernelfn::{LaplaceSlp, MatrixGen};
use hmatc::lowrank::AcaOptions;
use hmatc::mvm::{mvm, MvmAlgorithm};
use hmatc::runtime::{PjrtEngine, TileEngine};
use hmatc::util::Rng;
use std::sync::Arc;

fn artifacts_dir() -> Option<String> {
    for dir in ["artifacts", "../artifacts"] {
        if std::path::Path::new(dir).join("dense_tile_mvm.hlo.txt").exists() {
            return Some(dir.to_string());
        }
    }
    None
}

#[test]
fn pjrt_client_starts() {
    let engine = PjrtEngine::new("artifacts").expect("PJRT CPU client");
    assert!(engine.platform().to_lowercase().contains("cpu") || !engine.platform().is_empty());
}

#[test]
fn dense_tile_artifact_executes() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    };
    let mut engine = PjrtEngine::new(&dir).unwrap();
    // batch of 64 tiles 64x64 — identity in tile 0, zeros elsewhere
    const B: usize = 64;
    const T: usize = 64;
    let mut tiles = vec![0f32; B * T * T];
    for i in 0..T {
        tiles[i * T + i] = 2.0; // tile 0 = 2·I
    }
    let mut xs = vec![0f32; B * T];
    for j in 0..T {
        xs[j] = j as f32;
    }
    let out = engine.execute_f32("dense_tile_mvm", &[(&tiles, &[B, T, T]), (&xs, &[B, T])]).unwrap();
    let ys = &out[0];
    for j in 0..T {
        assert!((ys[j] - 2.0 * j as f32).abs() < 1e-4, "y[{j}] = {}", ys[j]);
    }
    for v in &ys[T..] {
        assert_eq!(*v, 0.0);
    }
}

#[test]
fn fpx_tile_artifact_matches_cpu_decode() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    };
    if !std::path::Path::new(&dir).join("fpx_tile_mvm_b2.hlo.txt").exists() {
        eprintln!("SKIP: fpx artifact missing");
        return;
    }
    let mut engine = PjrtEngine::new(&dir).unwrap();
    const B: usize = 64;
    const T: usize = 64;
    // build a tile of bf16-like truncated values: 2-byte FPX32 words packed
    // two-per-u32 (little endian)
    let mut rng = Rng::new(99);
    let mut vals = vec![0f32; T * T];
    for v in vals.iter_mut() {
        *v = f32::from_bits((((rng.normal() as f32).to_bits() >> 16) << 16) & 0xFFFF0000);
    }
    // pack: word index w holds values 2w (low 16) and 2w+1 (high 16)
    let mut words = vec![0u32; B * T * T / 2];
    for (i, v) in vals.iter().enumerate() {
        let half = (v.to_bits() >> 16) as u32;
        let w = i / 2;
        if i % 2 == 0 {
            words[w] |= half;
        } else {
            words[w] |= half << 16;
        }
    }
    let mut xs = vec![0f32; B * T];
    for j in 0..T {
        xs[j] = rng.normal() as f32;
    }
    let out = engine
        .execute_mixed("fpx_tile_mvm_b2", &[(&words, &[B, T * T / 2])], &[(&xs, &[B, T])])
        .unwrap();
    let ys = &out[0];
    // CPU reference on tile 0 (row-major tile)
    for i in 0..T {
        let mut acc = 0f32;
        for j in 0..T {
            acc += vals[i * T + j] * xs[j];
        }
        assert!((ys[i] - acc).abs() <= 1e-3 * (1.0 + acc.abs()), "row {i}: {} vs {acc}", ys[i]);
    }
}

#[test]
fn tile_engine_full_mvm_matches_pure_rust() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    };
    let geom = icosphere(2);
    let gen = LaplaceSlp::new(&geom);
    let ct = Arc::new(ClusterTree::build(gen.points(), 64));
    let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
    let h = HMatrix::build(&bt, &gen, &AcaOptions::with_eps(1e-6));
    let mut te = TileEngine::new(&dir, "dense_tile_mvm").unwrap();
    let n = h.nrows();
    let mut rng = Rng::new(55);
    let x = rng.vector(n);
    let mut y_pjrt = vec![0.0; n];
    let ntiles = te.full_mvm(1.0, &h, &x, &mut y_pjrt).unwrap();
    assert!(ntiles > 0, "no dense tiles offloaded");
    let mut y_rust = vec![0.0; n];
    mvm(1.0, &h, &x, &mut y_rust, MvmAlgorithm::Seq);
    let norm: f64 = y_rust.iter().map(|v| v * v).sum::<f64>().sqrt();
    let diff: f64 = y_rust.iter().zip(&y_pjrt).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
    // dense tiles ran in f32 on PJRT → f32-level agreement
    assert!(diff < 1e-5 * norm, "diff {diff} vs norm {norm}");
}
