//! Forced-scalar vs dispatched-SIMD equivalence for the codec kernel tables.
//!
//! `dispatch::force_simd` mutates the **process-global** ISA selection, so
//! these checks live in their own test binary with a single `#[test]` fn —
//! cargo's in-binary test threads can never observe a level another test
//! forced, and the `HMATC_SIMD=scalar` CI job keeps its other binaries pinned
//! to the scalar kernels throughout.
//!
//! Asserted here, window by window over every reachable byte width:
//!
//! * `decompress_range` decodes to identical bits under the forced-scalar and
//!   dispatched (AVX2 where detected) tables, for every `(begin, end)` window
//!   including `begin == end`, unaligned begins/ends and the FPX32 tail
//!   region where a 4-byte gather still fits but an 8-byte load does not;
//! * the fused `dot` performs the identical sequence of rounded operations on
//!   both ISA levels (stride-4 lane sums, serial tail into lane 0, fixed
//!   reduction) — bitwise-equal results;
//! * the fused `axpy` is bitwise ISA-independent (per-element mul + add).
//!
//! On machines without AVX2 the "dispatched" side resolves to scalar and the
//! comparisons are trivially true.

use hmatc::compress::dispatch::{self, SimdLevel};
use hmatc::compress::{Blob, Codec, DecodeCursor};
use hmatc::util::Rng;

fn cases() -> Vec<(Codec, Blob)> {
    // (codec, eps list, generator): covers AFLP widths 1..=8, FPX32 2..=4
    // (plain normals), FPX64 3..=8 (1e40 sentinel forces the FP64 format)
    let aflp_eps = [1e-1, 1e-3, 1e-5, 1e-7, 1e-9, 1e-11, 1e-13, 1e-15];
    let fpx32_eps = [1e-2, 1e-4, 1.2e-7];
    let fpx64_eps = [1e-2, 1e-6, 4e-9, 1.5e-11, 6e-14, 1e-16];
    let mut cases: Vec<(Codec, Blob)> = Vec::new();
    for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 11, 16, 21] {
        for (ei, &eps) in aflp_eps.iter().enumerate() {
            let mut rng = Rng::new(9000 + (ei * 100 + n) as u64);
            let data: Vec<f64> = (0..n).map(|i| if i % 5 == 4 { 0.0 } else { 1.0 + rng.uniform() }).collect();
            cases.push((Codec::Aflp, Blob::compress(Codec::Aflp, &data, eps)));
        }
        for (ei, &eps) in fpx32_eps.iter().enumerate() {
            let mut rng = Rng::new(9100 + (ei * 100 + n) as u64);
            let data: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            cases.push((Codec::Fpx, Blob::compress(Codec::Fpx, &data, eps)));
        }
        for (ei, &eps) in fpx64_eps.iter().enumerate() {
            let mut rng = Rng::new(9200 + (ei * 100 + n) as u64);
            let data: Vec<f64> = (0..n).map(|i| if i == 0 { 1.0e40 } else { rng.normal() }).collect();
            cases.push((Codec::Fpx, Blob::compress(Codec::Fpx, &data, eps)));
        }
    }
    cases
}

#[test]
fn forced_scalar_matches_dispatched_simd_bitwise() {
    let cases = cases();

    // -- range decode: every (begin, end) window, bit for bit --
    for (codec, blob) in &cases {
        let n = blob.n;
        for begin in 0..=n {
            for end in begin..=n {
                let mut scalar = vec![0.0f64; end - begin];
                let mut simd = vec![0.0f64; end - begin];
                dispatch::force_simd(Some(SimdLevel::Scalar));
                blob.decompress_range(begin, end, &mut scalar);
                dispatch::force_simd(Some(SimdLevel::Avx2));
                blob.decompress_range(begin, end, &mut simd);
                for (k, (a, b)) in scalar.iter().zip(&simd).enumerate() {
                    assert!(
                        a.to_bits() == b.to_bits(),
                        "{codec:?} b/val={} n={n} range {begin}..{end} idx {}: scalar {a:e} vs simd {b:e}",
                        blob.bytes_per_value(),
                        begin + k
                    );
                }
            }
        }
    }

    // -- fused dot + axpy: identical rounded-operation sequences per level --
    let mut rng = Rng::new(47);
    for (codec, blob) in &cases {
        let n = blob.n;
        if n == 0 {
            continue;
        }
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        dispatch::force_simd(Some(SimdLevel::Scalar));
        let ds = DecodeCursor::new(blob).dot(&x);
        let mut ys = x.clone();
        DecodeCursor::new(blob).axpy(1.7, &mut ys);
        dispatch::force_simd(Some(SimdLevel::Avx2));
        let dv = DecodeCursor::new(blob).dot(&x);
        let mut yv = x.clone();
        DecodeCursor::new(blob).axpy(1.7, &mut yv);
        assert_eq!(ds.to_bits(), dv.to_bits(), "{codec:?} n={n} fused dot");
        for (i, (a, b)) in ys.iter().zip(&yv).enumerate() {
            assert!(a.to_bits() == b.to_bits(), "{codec:?} n={n} fused axpy idx {i}");
        }
    }

    dispatch::force_simd(None);
}
