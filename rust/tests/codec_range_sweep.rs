//! Exhaustive `decompress_range(begin, end)` sweeps over tiny buffers: pins
//! the 4/8-byte-load window logic of the dispatch kernels against the scalar
//! random-access reference (`Blob::get`, robust byte assembly), for every
//! reachable value width. Decode kernels are selected by **runtime** ISA
//! dispatch; `tests/codec_simd_dispatch.rs` (its own binary, so the global
//! ISA override cannot race this suite) additionally asserts forced-scalar
//! vs dispatched-SIMD bitwise equivalence window by window, and CI runs the
//! whole suite under `HMATC_SIMD=scalar` so the scalar kernels stay pinned
//! end to end.
//!
//! The VALR sweeps run the same boundary checks over the *per-column* blobs
//! a `ZLowRankValr` block/basis stores: VALR picks a different accuracy (and
//! thus value width) per column, so one compressed block exercises many
//! codec configurations at streaming-kernel-relevant lengths.

use hmatc::compress::{Blob, Codec, ZLowRankValr};
use hmatc::la::DMatrix;
use hmatc::lowrank::LowRank;
use hmatc::util::Rng;
use std::collections::BTreeSet;

/// Every (begin, end) pair must decode bit-identically to per-index random
/// access, which never takes the vectorized fast paths' window shortcuts.
fn check_all_ranges(blob: &Blob, tag: &str) {
    let n = blob.n;
    let mut reference = vec![0.0f64; n];
    for (i, r) in reference.iter_mut().enumerate() {
        *r = blob.get(i);
    }
    for begin in 0..=n {
        for end in begin..=n {
            let mut out = vec![0.0f64; end - begin];
            blob.decompress_range(begin, end, &mut out);
            for (k, v) in out.iter().enumerate() {
                let want = reference[begin + k];
                assert!(
                    v.to_bits() == want.to_bits(),
                    "{tag}: n={n} range {begin}..{end} idx {}: {v:e} vs {want:e}",
                    begin + k
                );
            }
        }
    }
}

/// Sweep n ∈ 0..16 × the given accuracies; returns the distinct value widths
/// (bytes per value) that were exercised.
fn sweep(codec: Codec, eps_list: &[f64], make: impl Fn(usize, u64) -> Vec<f64>) -> BTreeSet<usize> {
    let mut widths = BTreeSet::new();
    for (ei, &eps) in eps_list.iter().enumerate() {
        for n in 0..16 {
            let data = make(n, (ei * 100 + n) as u64);
            let blob = Blob::compress(codec, &data, eps);
            if n > 0 {
                widths.insert(blob.bytes_per_value());
            }
            check_all_ranges(&blob, &format!("{codec:?} eps={eps} n={n}"));
        }
    }
    widths
}

#[test]
fn aflp_range_sweep_all_widths() {
    // narrow-range data keeps e_bits small so eps drives bytes_per across
    // the whole 1..=8 span; zeros exercise the zero-marker select
    let eps = [1e-1, 1e-3, 1e-5, 1e-7, 1e-9, 1e-11, 1e-13, 1e-15];
    let widths = sweep(Codec::Aflp, &eps, |n, seed| {
        let mut rng = Rng::new(1000 + seed);
        (0..n).map(|i| if i % 5 == 4 { 0.0 } else { 1.0 + rng.uniform() }).collect()
    });
    assert!(widths.len() >= 5, "aflp bytes_per coverage too thin: {widths:?}");
}

#[test]
fn aflp_extreme_range_sweep() {
    // wide dynamic range routes through the generic decode path (e_bits ≥ 11)
    for n in 1..12usize {
        let data: Vec<f64> = (0..n)
            .map(|i| if i % 2 == 0 { 1e-200 * (i + 1) as f64 } else { 1e200 / i as f64 })
            .collect();
        let blob = Blob::compress(Codec::Aflp, &data, 1e-4);
        check_all_ranges(&blob, &format!("aflp wide n={n}"));
    }
}

#[test]
fn aflp_wide_mantissa_sweep() {
    // eps beyond FP64 precision → m_bits > 52, generic decode path
    for n in 1..12usize {
        let data: Vec<f64> = (0..n).map(|i| 1.0 + i as f64 / 16.0).collect();
        let blob = Blob::compress(Codec::Aflp, &data, 1e-16);
        check_all_ranges(&blob, &format!("aflp wide-mantissa n={n}"));
    }
}

#[test]
fn fpx32_range_sweep_all_widths() {
    // FP32 base format: 2, 3 and 4 bytes per value
    let eps = [1e-2, 1e-4, 1.2e-7];
    let widths = sweep(Codec::Fpx, &eps, |n, seed| {
        let mut rng = Rng::new(2000 + seed);
        (0..n).map(|i| if i % 7 == 6 { 0.0 } else { rng.normal() }).collect()
    });
    for w in [2usize, 3, 4] {
        assert!(widths.contains(&w), "fpx32 width {w} not exercised: {widths:?}");
    }
}

/// A low-rank block with prescribed singular decay σ_i = decay^i (the regime
/// VALR is built for: tail columns tolerate coarse storage).
fn decaying_block(m: usize, n: usize, k: usize, decay: f64, seed: u64) -> LowRank {
    let mut rng = Rng::new(seed);
    let (qu, _) = hmatc::la::qr_thin(&DMatrix::random(m, k, &mut rng));
    let (qv, _) = hmatc::la::qr_thin(&DMatrix::random(n, k, &mut rng));
    let mut v = qv;
    for i in 0..k {
        let s = decay.powi(i as i32);
        for x in v.col_mut(i) {
            *x *= s;
        }
    }
    LowRank { u: qu, v }
}

/// Sweep every per-column blob of a VALR block for all (begin, end) pairs;
/// returns the distinct value widths exercised across the columns.
fn check_valr(z: &ZLowRankValr, tag: &str) -> BTreeSet<usize> {
    let mut widths = BTreeSet::new();
    for (i, blob) in z.wcols.iter().enumerate() {
        widths.insert(blob.bytes_per_value());
        check_all_ranges(blob, &format!("{tag} wcol {i}"));
    }
    for (i, blob) in z.xcols.iter().enumerate() {
        widths.insert(blob.bytes_per_value());
        check_all_ranges(blob, &format!("{tag} xcol {i}"));
    }
    widths
}

#[test]
fn valr_lowrank_range_sweep_both_codecs() {
    // small row/col counts keep the exhaustive (begin, end) sweep cheap while
    // still crossing the vectorized decoders' window cutoffs
    for codec in [Codec::Aflp, Codec::Fpx] {
        let mut widths = BTreeSet::new();
        for &(m, n, k) in &[(5usize, 4usize, 3usize), (11, 9, 6), (16, 13, 8)] {
            for &eps in &[1e-4, 1e-8, 1e-12] {
                let lr = decaying_block(m, n, k, 0.15, 7000 + m as u64);
                let z = ZLowRankValr::compress_lowrank(&lr, codec, eps);
                widths.extend(check_valr(&z, &format!("valr {codec:?} m={m} n={n} k={k} eps={eps}")));
            }
        }
        // strong decay + eps sweep must traverse several per-column widths
        assert!(widths.len() >= 3, "valr {codec:?} width coverage too thin: {widths:?}");
    }
}

#[test]
fn valr_basis_range_sweep() {
    // cluster-basis variant: only the W factor, same per-column rule
    let mut rng = Rng::new(7100);
    let (w, _) = hmatc::la::qr_thin(&DMatrix::random(13, 6, &mut rng));
    let sigma: Vec<f64> = (0..6).map(|i| 0.2f64.powi(i)).collect();
    for codec in [Codec::Aflp, Codec::Fpx] {
        for &eps in &[1e-5, 1e-10] {
            let z = ZLowRankValr::compress_basis(&w, &sigma, codec, eps);
            assert!(z.xcols.is_empty());
            check_valr(&z, &format!("valr basis {codec:?} eps={eps}"));
        }
    }
}

#[test]
fn valr_zero_and_rank_deficient_columns() {
    // σ = 0 tail columns get the coarsest accuracy; zero data must round-trip
    // through the Zero params and every range of an all-zero blob
    let mut rng = Rng::new(7200);
    let (qu, _) = hmatc::la::qr_thin(&DMatrix::random(9, 4, &mut rng));
    let mut v = DMatrix::random(7, 4, &mut rng);
    for c in [2usize, 3] {
        for x in v.col_mut(c) {
            *x = 0.0;
        }
    }
    let z = ZLowRankValr::compress_lowrank(&LowRank { u: qu, v }, Codec::Aflp, 1e-8);
    check_valr(&z, "valr zero-tail");
}

#[test]
fn fpx64_range_sweep_all_widths() {
    // a 1e40-scale sentinel forces the FP64 base format; eps drives 3..=8
    let eps = [1e-2, 1e-6, 4e-9, 1.5e-11, 6e-14, 1e-16];
    let widths = sweep(Codec::Fpx, &eps, |n, seed| {
        let mut rng = Rng::new(3000 + seed);
        (0..n).map(|i| if i == 0 { 1.0e40 } else { rng.normal() }).collect()
    });
    for w in [3usize, 4, 5, 6, 7, 8] {
        assert!(widths.contains(&w), "fpx64 width {w} not exercised: {widths:?}");
    }
}
