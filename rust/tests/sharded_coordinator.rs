//! Sharded tier pinning: row partitions + shard plans + scatter/gather
//! server must reproduce the unsharded plan **bitwise** — for all three
//! formats, compressed and uncompressed, shards ∈ {1, 2, 3}, forward /
//! adjoint / multi-RHS, and from nonzero seeds — plus the admission-control
//! and shard-failure error paths (rejections fail fast, panics surface as
//! errors, nothing hangs).

use hmatc::cluster::{BlockTree, ClusterTree, StdAdmissibility};
use hmatc::compress::{Codec, CompressionConfig};
use hmatc::coordinator::{BatchPolicy, MvmServer, ServeError};
use hmatc::geometry::icosphere;
use hmatc::hmatrix::HMatrix;
use hmatc::kernelfn::{LaplaceSlp, MatrixGen};
use hmatc::la::DMatrix;
use hmatc::lowrank::AcaOptions;
use hmatc::plan::{row_partition, ExecutorKind, HOperator, PlannedOperator, ShardPlan};
use hmatc::util::Rng;
use std::sync::Arc;
use std::time::Duration;

fn build_h(level: usize, eps: f64) -> HMatrix {
    let geom = icosphere(level);
    let gen = LaplaceSlp::new(&geom);
    let ct = Arc::new(ClusterTree::build(gen.points(), 16));
    let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
    HMatrix::build(&bt, &gen, &AcaOptions::with_eps(eps))
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(x.to_bits() == y.to_bits(), "{what}: row {i}: {x:e} vs {y:e}");
    }
}

/// Forward, adjoint and multi-RHS against the unsharded plan, shards 1..=3,
/// nonzero seeds: reassembling every shard's owned rows must reproduce the
/// unsharded output bit for bit.
fn check_sharded_matches_unsharded(op: &PlannedOperator, tag: &str) {
    let (nr, nc) = (op.nrows(), op.ncols());
    let mut rng = Rng::new(777);
    let alpha = 1.25;
    for shards in [1usize, 2, 3] {
        let specs = row_partition(op, shards).expect("partition");
        assert_eq!(specs.len(), shards);
        let plans: Vec<ShardPlan> = specs.into_iter().map(|s| ShardPlan::build(op, s, ExecutorKind::StaticLpt)).collect();

        // forward, accumulating onto a nonzero seed
        let x = rng.vector(nc);
        let seed = rng.vector(nr);
        let mut want = seed.clone();
        op.apply(alpha, &x, &mut want);
        let mut got = seed.clone();
        for p in &plans {
            let rows = p.owned(false);
            let mut out = vec![0.0; rows.len()];
            p.apply_owned(false, alpha, &x, Some(&seed), &mut out);
            got[rows].copy_from_slice(&out);
        }
        assert_bits_eq(&got, &want, &format!("{tag} fwd shards={shards}"));

        // adjoint: partitioned along the column tree
        let xa = rng.vector(nr);
        let seed_adj = rng.vector(nc);
        let mut want = seed_adj.clone();
        op.apply_adjoint(alpha, &xa, &mut want);
        let mut got = seed_adj.clone();
        for p in &plans {
            let rows = p.owned(true);
            let mut out = vec![0.0; rows.len()];
            p.apply_owned(true, alpha, &xa, Some(&seed_adj), &mut out);
            got[rows].copy_from_slice(&out);
        }
        assert_bits_eq(&got, &want, &format!("{tag} adj shards={shards}"));

        // multi-RHS with a seed panel, and the None = zero-seed path
        let b = 3usize;
        let xm = DMatrix::random(nc, b, &mut rng);
        let seedm = DMatrix::random(nr, b, &mut rng);
        let mut wantm = seedm.clone();
        op.apply_multi(alpha, &xm, &mut wantm);
        let mut gotm = seedm.clone();
        let mut wantz = DMatrix::zeros(nr, b);
        op.apply_multi(alpha, &xm, &mut wantz);
        let mut gotz = DMatrix::zeros(nr, b);
        for p in &plans {
            let rows = p.owned(false);
            let mut out = DMatrix::zeros(rows.len(), b);
            p.apply_multi_owned(false, alpha, &xm, Some(&seedm), &mut out);
            for c in 0..b {
                gotm.col_mut(c)[rows.clone()].copy_from_slice(out.col(c));
            }
            p.apply_multi_owned(false, alpha, &xm, None, &mut out);
            for c in 0..b {
                gotz.col_mut(c)[rows.clone()].copy_from_slice(out.col(c));
            }
        }
        assert_bits_eq(gotm.data(), wantm.data(), &format!("{tag} multi shards={shards}"));
        assert_bits_eq(gotz.data(), wantz.data(), &format!("{tag} multi-zero shards={shards}"));
    }
}

#[test]
fn sharded_h_plans_match_unsharded_bitwise() {
    let h0 = build_h(2, 1e-7);
    for compress in [false, true] {
        let mut h = h0.clone();
        if compress {
            h.compress(&CompressionConfig { codec: Codec::Aflp, eps: 1e-9, valr: true });
        }
        let op = PlannedOperator::from_h_with(Arc::new(h), ExecutorKind::StaticLpt);
        check_sharded_matches_unsharded(&op, &format!("H compress={compress}"));
    }
}

#[test]
fn sharded_uh_plans_match_unsharded_bitwise() {
    let h0 = build_h(2, 1e-7);
    for compress in [false, true] {
        let mut uh = hmatc::uniform::build_from_h(&h0, 1e-6, hmatc::uniform::CouplingKind::Combined);
        if compress {
            uh.compress(&CompressionConfig { codec: Codec::Fpx, eps: 1e-9, valr: true });
        }
        let op = PlannedOperator::from_uniform_with(Arc::new(uh), ExecutorKind::StaticLpt);
        check_sharded_matches_unsharded(&op, &format!("UH compress={compress}"));
    }
}

#[test]
fn sharded_h2_plans_match_unsharded_bitwise() {
    let h0 = build_h(2, 1e-7);
    for compress in [false, true] {
        let mut h2 = hmatc::h2::build_from_h(&h0, 1e-6);
        if compress {
            h2.compress(&CompressionConfig { codec: Codec::Aflp, eps: 1e-9, valr: true });
        }
        let op = PlannedOperator::from_h2_with(Arc::new(h2), ExecutorKind::StaticLpt);
        check_sharded_matches_unsharded(&op, &format!("H2 compress={compress}"));
    }
}

#[test]
fn row_partition_covers_the_domain_with_disjoint_ordered_ranges() {
    let h = Arc::new(build_h(2, 1e-7));
    let op = PlannedOperator::from_h_with(h, ExecutorKind::StaticLpt);
    assert!(row_partition(&op, 0).is_err(), "zero shards must be rejected");
    for shards in [1usize, 2, 3, 5] {
        let specs = row_partition(&op, shards).unwrap();
        assert_eq!(specs.len(), shards);
        let mut next = 0usize;
        let mut total_cost = 0.0;
        for (i, s) in specs.iter().enumerate() {
            assert_eq!(s.index, i);
            assert_eq!(s.count, shards);
            if !s.rows.is_empty() {
                assert_eq!(s.rows.start, next, "shard {i}: owned rows must be contiguous");
                next = s.rows.end;
            }
            total_cost += s.cost;
        }
        assert_eq!(next, op.nrows(), "shards={shards}: rows not covered");
        assert!(total_cost > 0.0);
    }
}

#[test]
fn sharded_server_matches_unsharded_server_bitwise() {
    let h = Arc::new(build_h(2, 1e-7));
    let op = Arc::new(PlannedOperator::from_h_with(h.clone(), ExecutorKind::StaticLpt));
    let mut rng = Rng::new(321);
    let xs: Vec<Vec<f64>> = (0..6).map(|_| rng.vector(h.ncols())).collect();
    let flat = MvmServer::start(op.clone(), BatchPolicy::default());
    let want: Vec<Vec<f64>> = xs.iter().map(|x| flat.call(x.clone()).y).collect();
    drop(flat);
    for shards in [1usize, 2, 3] {
        let server = MvmServer::start_sharded(op.clone(), shards, ExecutorKind::StaticLpt, BatchPolicy::default())
            .expect("sharded server starts");
        for (x, w) in xs.iter().zip(&want) {
            let got = server.call(x.clone()).y;
            assert_bits_eq(&got, w, &format!("served shards={shards}"));
        }
        let line = server.metrics.shard_summary().expect("sharded metrics summary");
        assert!(line.starts_with(&format!("shards: {shards}")), "unexpected summary: {line}");
        let snap = server.metrics.snapshot();
        assert_eq!(snap.requests, xs.len());
    }
}

#[test]
fn queue_limit_rejects_excess_requests_without_dropping_admitted_ones() {
    let h = Arc::new(build_h(1, 1e-6));
    let op = Arc::new(PlannedOperator::from_h_with(h.clone(), ExecutorKind::StaticLpt));
    // long linger: the first batch stays open while we overfill the backlog
    let policy = BatchPolicy { max_batch: 8, linger: Duration::from_millis(500), queue_limit: 2, shard_queue: 1 };
    let server = MvmServer::start_sharded(op, 2, ExecutorKind::StaticLpt, policy).expect("sharded server starts");
    let mut rng = Rng::new(9);
    let n = h.ncols();
    let rx1 = server.submit(rng.vector(n));
    let rx2 = server.submit(rng.vector(n));
    let rx3 = server.submit(rng.vector(n)); // pending == limit: rejected at the door
    match rx3.recv().unwrap() {
        Err(ServeError::Rejected { pending, limit }) => {
            assert_eq!(limit, 2);
            assert!(pending >= 2, "pending {pending}");
        }
        other => panic!("expected rejection, got {other:?}"),
    }
    // the admitted requests still complete normally
    let r1 = rx1.recv().unwrap().expect("admitted request served");
    let r2 = rx2.recv().unwrap().expect("admitted request served");
    assert_eq!(r1.y.len(), h.nrows());
    assert_eq!(r2.y.len(), h.nrows());
    assert_eq!(server.metrics.rejected(), 1);
}

#[test]
fn shard_panic_surfaces_as_error_and_the_tier_keeps_serving() {
    let h = Arc::new(build_h(1, 1e-6));
    let op = Arc::new(PlannedOperator::from_h_with(h.clone(), ExecutorKind::StaticLpt));
    let server = MvmServer::start_sharded(op, 2, ExecutorKind::StaticLpt, BatchPolicy::default()).expect("sharded server starts");
    let mut rng = Rng::new(11);
    let x = rng.vector(h.ncols());
    let healthy = server.try_call(x.clone()).expect("healthy call");
    server.inject_shard_fault(1);
    match server.try_call(x.clone()) {
        Err(ServeError::ShardFailed { shard, message }) => {
            assert_eq!(shard, 1);
            assert!(message.contains("injected shard fault"), "message: {message}");
        }
        other => panic!("expected ShardFailed, got {other:?}"),
    }
    // the worker contained the panic: the next request is served, bitwise
    // equal to the pre-fault response, and the server still drops cleanly
    let again = server.try_call(x).expect("post-fault call");
    assert_bits_eq(&again.y, &healthy.y, "post-fault response");
    drop(server); // must not hang
}
