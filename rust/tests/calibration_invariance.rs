//! Cost-model calibration invariants: `rebalance`/`calibrate` only
//! re-partition a plan's task lists, so products must be **bitwise
//! identical** before and after — for all three formats (H/UH/H²),
//! compressed and uncompressed, forward + adjoint + multi-RHS, across the
//! `lpt`/`steal`/`sharded:2` backends. Plus: the re-balancer never increases
//! the modeled makespan on synthetic skewed cost distributions, the timing
//! accumulators stay consistent under work-stealing oversubscription and
//! zero-worker pools, and profile files (incl. `HMATC_COSTS`) reject hostile
//! input without panicking.

use hmatc::cluster::{BlockTree, ClusterTree, StdAdmissibility};
use hmatc::compress::{Codec, CompressionConfig};
use hmatc::geometry::icosphere;
use hmatc::hmatrix::HMatrix;
use hmatc::kernelfn::{LaplaceSlp, MatrixGen};
use hmatc::la::DMatrix;
use hmatc::lowrank::AcaOptions;
use hmatc::par::{Scope, StealSet, ThreadPool};
use hmatc::plan::costmodel::{makespan, rebalance_levels, CodecFamily, CostProfile, CostSource, KernelClass};
use hmatc::plan::schedule::{balance_level, Shard};
use hmatc::plan::{ExecutorKind, HOperator, PlannedOperator, TimingSink};
use hmatc::util::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

fn build_h(level: usize, eps: f64) -> HMatrix {
    let geom = icosphere(level);
    let gen = LaplaceSlp::new(&geom);
    let ct = Arc::new(ClusterTree::build(gen.points(), 16));
    let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
    HMatrix::build(&bt, &gen, &AcaOptions::with_eps(eps))
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(x.to_bits() == y.to_bits(), "{what}: row {i}: {x:e} vs {y:e}");
    }
}

/// The backends the invariance matrix covers.
fn kinds() -> [ExecutorKind; 3] {
    [ExecutorKind::StaticLpt, ExecutorKind::WorkStealing, ExecutorKind::Sharded(2)]
}

/// A deliberately skewed synthetic profile: decode bytes an order of
/// magnitude more expensive than plain streamed bytes, flops and vector
/// traffic in between — very different relative task weights than the
/// static byte model, so the re-balancer really re-partitions.
fn skewed_profile(seed: u64) -> CostProfile {
    let mut rng = Rng::new(seed);
    let mut coeffs = vec![
        (KernelClass::MatBytes, 1e-10 * (1.0 + rng.uniform())),
        (KernelClass::DenseFlop, 3e-10 * (1.0 + rng.uniform())),
        (KernelClass::LowRankFlop, 7e-10 * (1.0 + rng.uniform())),
        (KernelClass::PanelVec, 2e-10 * (1.0 + rng.uniform())),
    ];
    for w in 1..=8u8 {
        for fam in [CodecFamily::Aflp, CodecFamily::Fpx32, CodecFamily::Fpx64] {
            coeffs.push((KernelClass::Decode(fam, w), 1e-9 * (0.5 + rng.uniform()) * w as f64));
        }
    }
    CostProfile::from_coeffs(&coeffs)
}

/// Forward (twice, pinning arena/packing reuse), adjoint and multi-RHS in
/// both directions.
fn run_all(op: &PlannedOperator, n: usize) -> (Vec<f64>, Vec<f64>, DMatrix, DMatrix) {
    let mut rng = Rng::new(515151);
    let x = rng.vector(n);
    let y0 = rng.vector(n);
    let xm = DMatrix::random(n, 3, &mut rng);
    let mut fwd = y0.clone();
    op.apply(0.75, &x, &mut fwd);
    op.apply(0.75, &x, &mut fwd);
    let mut adj = y0.clone();
    op.apply_adjoint(0.75, &x, &mut adj);
    let mut multi = DMatrix::zeros(n, 3);
    op.apply_multi(0.75, &xm, &mut multi);
    let mut multi_adj = DMatrix::zeros(n, 3);
    op.apply_multi_adjoint(0.75, &xm, &mut multi_adj);
    (fwd, adj, multi, multi_adj)
}

fn check_rebalance_invariant(op: &PlannedOperator, n: usize, tag: &str) {
    let (bf, ba, bm, bma) = run_all(op, n);
    // two successive re-balances with different skews: the second starts
    // from an already-calibrated packing
    for (round, seed) in [(1usize, 99u64), (2, 1234)] {
        let profile = skewed_profile(seed);
        op.rebalance(&profile);
        assert_eq!(op.plan_stats().cost_source, CostSource::Online, "{tag} round {round}");
        let (f, a, m, ma) = run_all(op, n);
        assert_bits_eq(&f, &bf, &format!("{tag} fwd round {round}"));
        assert_bits_eq(&a, &ba, &format!("{tag} adj round {round}"));
        assert_bits_eq(m.data(), bm.data(), &format!("{tag} multi round {round}"));
        assert_bits_eq(ma.data(), bma.data(), &format!("{tag} multi-adj round {round}"));
    }
}

#[test]
fn rebalance_is_bitwise_output_invariant_h() {
    let h0 = build_h(2, 1e-7);
    let n = h0.nrows();
    for compress in [false, true] {
        let mut h = h0.clone();
        if compress {
            h.compress(&CompressionConfig { codec: Codec::Aflp, eps: 1e-9, valr: true });
        }
        let h = Arc::new(h);
        for kind in kinds() {
            let op = PlannedOperator::from_h_with(h.clone(), kind);
            check_rebalance_invariant(&op, n, &format!("H compress={compress} [{kind}]"));
        }
    }
}

#[test]
fn rebalance_is_bitwise_output_invariant_uh() {
    let h = build_h(2, 1e-7);
    let n = h.nrows();
    for compress in [false, true] {
        let mut uh = hmatc::uniform::build_from_h(&h, 1e-6, hmatc::uniform::CouplingKind::Combined);
        if compress {
            uh.compress(&CompressionConfig { codec: Codec::Fpx, eps: 1e-9, valr: true });
        }
        let uh = Arc::new(uh);
        for kind in kinds() {
            let op = PlannedOperator::from_uniform_with(uh.clone(), kind);
            check_rebalance_invariant(&op, n, &format!("UH compress={compress} [{kind}]"));
        }
    }
}

#[test]
fn rebalance_is_bitwise_output_invariant_h2() {
    let h = build_h(2, 1e-7);
    let n = h.nrows();
    for compress in [false, true] {
        let mut h2 = hmatc::h2::build_from_h(&h, 1e-6);
        if compress {
            h2.compress(&CompressionConfig { codec: Codec::Aflp, eps: 1e-9, valr: true });
        }
        let h2 = Arc::new(h2);
        for kind in kinds() {
            let op = PlannedOperator::from_h2_with(h2.clone(), kind);
            check_rebalance_invariant(&op, n, &format!("H2 compress={compress} [{kind}]"));
        }
    }
}

/// In-process calibration (timed rounds + fit + re-balance) is also output
/// invariant — the timed wrapper must not perturb results either.
#[test]
fn calibrate_is_bitwise_output_invariant_all_formats() {
    let h = build_h(2, 1e-7);
    let n = h.nrows();
    let cfg = CompressionConfig { codec: Codec::Aflp, eps: 1e-9, valr: true };
    let mut hz = h.clone();
    hz.compress(&cfg);
    let mut uh = hmatc::uniform::build_from_h(&h, 1e-6, hmatc::uniform::CouplingKind::Combined);
    uh.compress(&cfg);
    let mut h2 = hmatc::h2::build_from_h(&h, 1e-6);
    h2.compress(&cfg);
    let (hz, uh, h2) = (Arc::new(hz), Arc::new(uh), Arc::new(h2));
    for kind in kinds() {
        let ops: Vec<(&str, PlannedOperator)> = vec![
            ("H", PlannedOperator::from_h_with(hz.clone(), kind)),
            ("UH", PlannedOperator::from_uniform_with(uh.clone(), kind)),
            ("H2", PlannedOperator::from_h2_with(h2.clone(), kind)),
        ];
        for (name, op) in &ops {
            let (bf, ba, bm, bma) = run_all(op, n);
            let profile = op.calibrate(2);
            for (class, coeff) in profile.coeffs() {
                assert!(coeff.is_finite() && *coeff >= 0.0, "{name} [{kind}] {}: {coeff}", class.key());
            }
            let (f, a, m, ma) = run_all(op, n);
            assert_bits_eq(&f, &bf, &format!("{name} fwd calibrated [{kind}]"));
            assert_bits_eq(&a, &ba, &format!("{name} adj calibrated [{kind}]"));
            assert_bits_eq(m.data(), bm.data(), &format!("{name} multi calibrated [{kind}]"));
            assert_bits_eq(ma.data(), bma.data(), &format!("{name} multi-adj calibrated [{kind}]"));
        }
    }
}

/// Per-pool overlay coefficients (the NUMA cost model) price each sub-pool's
/// bins under that pool's own rates, so the packer can hand a "slow" pool
/// fewer bytes — but it still only moves tasks between shards, so products
/// stay bitwise identical. Pools get deliberately divergent overlay rates
/// (0.4× / 2.5× / 5×) to force genuinely asymmetric packings. The
/// pinned-vs-unpinned half of the invariance is cross-process by nature
/// (topology discovery is a process-wide `OnceLock`): CI re-runs this whole
/// suite under `HMATC_PIN=0`, and pinning only moves threads, never work.
#[test]
fn per_pool_rebalance_is_bitwise_output_invariant() {
    let h = build_h(2, 1e-7);
    let n = h.nrows();
    let cfg = CompressionConfig { codec: Codec::Aflp, eps: 1e-9, valr: true };
    let mut hz = h.clone();
    hz.compress(&cfg);
    let mut uh = hmatc::uniform::build_from_h(&h, 1e-6, hmatc::uniform::CouplingKind::Combined);
    uh.compress(&cfg);
    let mut h2 = hmatc::h2::build_from_h(&h, 1e-6);
    h2.compress(&cfg);
    let (hz, uh, h2) = (Arc::new(hz), Arc::new(uh), Arc::new(h2));
    for npools in [2usize, 3] {
        let kind = ExecutorKind::Sharded(npools);
        let ops: Vec<(&str, PlannedOperator)> = vec![
            ("H", PlannedOperator::from_h_with(hz.clone(), kind)),
            ("UH", PlannedOperator::from_uniform_with(uh.clone(), kind)),
            ("H2", PlannedOperator::from_h2_with(h2.clone(), kind)),
        ];
        for (name, op) in &ops {
            let (bf, ba, bm, bma) = run_all(op, n);
            let base = skewed_profile(4242);
            let overlays: Vec<_> = [0.4f64, 2.5, 5.0]
                .iter()
                .take(npools)
                .map(|&f| base.coeffs().iter().map(|(c, v)| (*c, v * f)).collect())
                .collect();
            let profile = base.with_pools(overlays);
            op.rebalance(&profile);
            let st = op.plan_stats();
            assert_eq!(st.cost_source, CostSource::Online, "{name} [{kind}]");
            assert_eq!(st.pool_cost_sources, vec!["per-pool"; npools], "{name} [{kind}]");
            let (f, a, m, ma) = run_all(op, n);
            assert_bits_eq(&f, &bf, &format!("{name} fwd per-pool [{kind}]"));
            assert_bits_eq(&a, &ba, &format!("{name} adj per-pool [{kind}]"));
            assert_bits_eq(m.data(), bm.data(), &format!("{name} multi per-pool [{kind}]"));
            assert_bits_eq(ma.data(), bma.data(), &format!("{name} multi-adj per-pool [{kind}]"));
        }
    }
}

/// The re-balancer keeps whichever packing models better, so on any cost
/// distribution — here heavy-tailed skews the static model never saw — the
/// modeled makespan cannot increase.
#[test]
fn calibrated_lpt_never_increases_modeled_makespan_on_synthetic_skew() {
    let mut rng = Rng::new(0xBEEF);
    for trial in 0..25usize {
        let n = 20 + (trial * 17) % 200;
        let static_costs: Vec<f64> = (0..n).map(|_| (1.0 + rng.uniform()) * 1000.0).collect();
        // measured costs: static × 10^U(-2,2) — heavy relative skew
        let true_costs: Vec<f64> = static_costs.iter().map(|c| c * 10f64.powf(rng.range(-2.0, 2.0))).collect();
        let scratch = vec![0usize; n];
        let ids: Vec<usize> = (0..n).collect();
        let (cut1, cut2) = (n / 4, n / 2);
        let level_ids: Vec<Vec<usize>> = [&ids[..cut1], &ids[cut1..cut2], &ids[cut2..]].iter().map(|l| l.to_vec()).filter(|l| !l.is_empty()).collect();
        for nshards in [2usize, 4, 9] {
            let old: Vec<Vec<Shard>> = level_ids.iter().map(|ids| balance_level(ids, &static_costs, &scratch, nshards)).collect();
            let new = rebalance_levels(&old, &level_ids, &true_costs, &scratch, nshards);
            let (m_new, m_old) = (makespan(&new, &true_costs), makespan(&old, &true_costs));
            assert!(m_new <= m_old * (1.0 + 1e-12), "trial {trial} nshards {nshards}: {m_new} > {m_old}");
            // every task still scheduled exactly once
            let mut seen = vec![false; n];
            for lv in &new {
                for s in lv {
                    for &t in &s.tasks {
                        assert!(!seen[t], "task {t} twice");
                        seen[t] = true;
                    }
                }
            }
            assert!(seen.iter().all(|&s| s));
        }
    }
}

// ---------------------------------------------------------------------------
// timing-accumulator stress (the instrumentation the executors write into)
// ---------------------------------------------------------------------------

fn spawn_tree<'e>(s: &Scope<'e>, depth: usize, sink: &'e TimingSink, slot: usize, count: &'e AtomicUsize) {
    sink.add(slot, 1e-9);
    count.fetch_add(1, Ordering::Relaxed);
    if depth > 0 {
        s.spawn(move |s2| spawn_tree(s2, depth - 1, sink, slot, count));
        s.spawn(move |s2| spawn_tree(s2, depth - 1, sink, slot, count));
    }
}

/// One worker, 12 stealing slots, a recursive spawn tree hammering a shared
/// accumulator slot while the steal run records per-chunk samples: every
/// sample must land exactly once and untorn, and per-shard totals must sum
/// to the level total.
#[test]
fn timing_sink_consistent_under_steal_oversubscription() {
    let pool = ThreadPool::new(1);
    let items = 300usize;
    let sink = TimingSink::new(items + 1); // slot `items` is the contended tree slot
    let tree_count = AtomicUsize::new(0);
    let mut set = StealSet::new();
    let set_ref = &mut set;
    let (pool_ref, sink_ref, tree_ref) = (&pool, &sink, &tree_count);
    pool.scope(|s| {
        s.spawn(move |s2| spawn_tree(s2, 7, sink_ref, items, tree_ref));
        s.spawn(move |_| {
            set_ref.run(pool_ref, 12, items, |_slot, item| {
                sink_ref.add(item, (item + 1) as f64 * 1e-9);
                if item % 97 == 0 {
                    std::thread::yield_now(); // jitter → force real steals
                }
            });
        });
    });
    // exactly-once, untorn per-chunk samples (known exact nanosecond values)
    for item in 0..items {
        assert_eq!(sink.secs(item), (item + 1) as f64 * 1e-9, "item {item}");
    }
    // the contended slot absorbed every concurrent fetch_add
    let tree_n = tree_count.load(Ordering::Relaxed);
    assert_eq!(tree_n, (1 << 8) - 1);
    assert_eq!(sink.secs(items), tree_n as f64 * 1e-9);
    // per-shard totals (an arbitrary partition of the level) sum to the
    // level total
    let shard_bounds = [0usize, 63, 120, 240, items];
    let mut shard_sum = 0.0;
    for w in shard_bounds.windows(2) {
        shard_sum += (w[0]..w[1]).map(|i| sink.secs(i)).sum::<f64>();
    }
    let level_total: f64 = (0..items).map(|i| sink.secs(i)).sum();
    assert!((shard_sum - level_total).abs() < 1e-12, "{shard_sum} vs {level_total}");
    assert!((sink.total() - level_total - sink.secs(items)).abs() < 1e-12);
}

#[test]
fn timing_sink_zero_worker_pool_progresses() {
    let pool = ThreadPool::new(0);
    let sink = TimingSink::new(40);
    let mut set = StealSet::new();
    for _ in 0..3 {
        set.run(&pool, 8, 40, |_slot, item| sink.add(item, 2e-9));
    }
    for item in 0..40 {
        // both sides compute 6_nanos as f64 * 1e-9, so equality is exact
        assert_eq!(sink.secs(item), 6.0 * 1e-9, "item {item}");
    }
    sink.reset();
    assert_eq!(sink.total(), 0.0);
}

// The profile-file round-trip / hostile-input / `HMATC_COSTS` fallback
// tests live in `tests/calibration_env.rs` — their **own binary**, because
// `std::env::set_var` racing any concurrent `getenv` (thread-pool init
// reading `HMATC_THREADS`, executor selection reading `HMATC_EXEC`) from
// parallel test threads is undefined behavior in glibc. Same isolation
// pattern as `tests/codec_simd_dispatch.rs`.
