//! End-to-end H-matrix construction accuracy + memory behaviour across
//! kernels, admissibility conditions and accuracies.

use hmatc::cluster::{BlockTree, ClusterTree, OffDiagAdmissibility, StdAdmissibility, WeakAdmissibility};
use hmatc::geometry::{circle_points, icosphere, random_cube};
use hmatc::kernelfn::{ExpCovariance, LaplaceSlp, LogKernel, Matern32Covariance, MatrixGen};
use hmatc::hmatrix::HMatrix;
use hmatc::la::DMatrix;
use hmatc::lowrank::AcaOptions;
use hmatc::util::Rng;
use std::sync::Arc;

fn dense_reference(gen: &dyn MatrixGen, ct: &ClusterTree) -> DMatrix {
    let n = ct.len();
    let mut d = DMatrix::zeros(n, n);
    for j in 0..n {
        for i in 0..n {
            d[(i, j)] = gen.entry(ct.perm[i], ct.perm[j]);
        }
    }
    d
}

fn check_accuracy(gen: &dyn MatrixGen, eps: f64, tol_factor: f64) {
    let ct = Arc::new(ClusterTree::build(gen.points(), 16));
    let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
    let h = HMatrix::build(&bt, gen, &AcaOptions::with_eps(eps));
    let dref = dense_reference(gen, &ct);
    let mut diff = h.to_dense();
    diff.add_scaled(-1.0, &dref);
    let rel = diff.fro_norm() / dref.fro_norm();
    assert!(rel < tol_factor * eps, "rel err {rel} (eps {eps})");
}

#[test]
fn laplace_slp_accuracy_sweep() {
    let geom = icosphere(2); // n = 320
    let gen = LaplaceSlp::new(&geom);
    for eps in [1e-4, 1e-6] {
        check_accuracy(&gen, eps, 30.0);
    }
}

#[test]
fn log_kernel_accuracy() {
    let gen = LogKernel::new(circle_points(256));
    check_accuracy(&gen, 1e-6, 30.0);
}

#[test]
fn covariance_kernels_accuracy() {
    let mut rng = Rng::new(42);
    let pts = random_cube(300, &mut rng);
    check_accuracy(&ExpCovariance::new(pts.clone(), 0.3), 1e-5, 50.0);
    check_accuracy(&Matern32Covariance::new(pts, 0.3), 1e-5, 50.0);
}

#[test]
fn weak_admissibility_coarser_partition() {
    let geom = icosphere(2);
    let gen = LaplaceSlp::new(&geom);
    let ct = Arc::new(ClusterTree::build(gen.points(), 16));
    let bt_std = BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0));
    let bt_weak = BlockTree::build(&ct, &ct, &WeakAdmissibility);
    // weak admissibility admits more blocks earlier → fewer leaves
    assert!(bt_weak.leaves.len() <= bt_std.leaves.len());
    bt_weak.validate_partition().unwrap();
}

#[test]
fn hodlr_construction_works() {
    let geom = icosphere(2);
    let gen = LaplaceSlp::new(&geom);
    let ct = Arc::new(ClusterTree::build(gen.points(), 32));
    let bt = Arc::new(BlockTree::build(&ct, &ct, &OffDiagAdmissibility));
    let h = HMatrix::build(&bt, &gen, &AcaOptions::with_eps(1e-4));
    let dref = dense_reference(&gen, &ct);
    let mut diff = h.to_dense();
    diff.add_scaled(-1.0, &dref);
    let rel = diff.fro_norm() / dref.fro_norm();
    assert!(rel < 1e-3, "HODLR rel err {rel}");
}

#[test]
fn blr_construction_works() {
    let geom = icosphere(2);
    let gen = LaplaceSlp::new(&geom);
    let ct = Arc::new(ClusterTree::build_blr(gen.points(), 64));
    let bt = Arc::new(BlockTree::build(&ct, &ct, &OffDiagAdmissibility));
    assert_eq!(bt.depth(), 1);
    let h = HMatrix::build(&bt, &gen, &AcaOptions::with_eps(1e-4));
    let dref = dense_reference(&gen, &ct);
    let mut diff = h.to_dense();
    diff.add_scaled(-1.0, &dref);
    let rel = diff.fro_norm() / dref.fro_norm();
    assert!(rel < 1e-3, "BLR rel err {rel}");
}

#[test]
fn memory_grows_subquadratically() {
    // bytes/dof must grow far slower than n (Fig. 1 left behaviour)
    let mut per_dof = Vec::new();
    for level in [1usize, 2, 3] {
        let geom = icosphere(level);
        let gen = LaplaceSlp::new(&geom);
        let ct = Arc::new(ClusterTree::build(gen.points(), 32));
        let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
        let h = HMatrix::build(&bt, &gen, &AcaOptions::with_eps(1e-4));
        per_dof.push(h.bytes_per_dof());
    }
    // dense would quadruple per level; H-matrix per-dof growth should be mild
    assert!(per_dof[2] < 2.5 * per_dof[1], "per-dof {per_dof:?}");
}

#[test]
fn fixed_rank_construction() {
    let geom = icosphere(2);
    let gen = LaplaceSlp::new(&geom);
    let ct = Arc::new(ClusterTree::build(gen.points(), 16));
    let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
    let h = HMatrix::build(&bt, &gen, &AcaOptions::with_rank(5));
    let st = h.stats();
    assert!(st.max_rank <= 5, "max rank {}", st.max_rank);
}
