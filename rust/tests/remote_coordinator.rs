//! Remote fleet pinning: the cross-process scatter/gather tier (couriers ↔
//! `serve_worker` over loopback TCP) must reproduce the in-process sharded
//! server **bitwise** — all three formats, compressed and uncompressed,
//! forward / adjoint / multi-RHS — and survive the failure paths: hostile
//! frames are rejected without taking the worker down, a killed worker is
//! replaced by a health-checked restart with in-flight replay, and the
//! fault-injection hook simulates a mid-stream crash that replays
//! transparently. Workers run as threads here (same binary, own sockets);
//! the CI smoke covers the separate-process topology.

use hmatc::cluster::{BlockTree, ClusterTree, StdAdmissibility};
use hmatc::compress::{Codec, CompressionConfig};
use hmatc::coordinator::{
    bind_listener, bind_listener_retry, serve_worker, BatchPolicy, MvmServer, RemoteConfig, RemoteShardClient,
};
use hmatc::geometry::icosphere;
use hmatc::hmatrix::HMatrix;
use hmatc::kernelfn::{LaplaceSlp, MatrixGen};
use hmatc::la::DMatrix;
use hmatc::lowrank::AcaOptions;
use hmatc::plan::{row_partition, ExecutorKind, HOperator, PlannedOperator, ShardPlan};
use hmatc::util::Rng;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

fn build_h(level: usize, eps: f64) -> HMatrix {
    let geom = icosphere(level);
    let gen = LaplaceSlp::new(&geom);
    let ct = Arc::new(ClusterTree::build(gen.points(), 16));
    let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
    HMatrix::build(&bt, &gen, &AcaOptions::with_eps(eps))
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(x.to_bits() == y.to_bits(), "{what}: row {i}: {x:e} vs {y:e}");
    }
}

/// Test-speed knobs: tight heartbeat so reconnect probes come fast, many
/// attempts so a restarting worker is always found before failover.
fn fast_cfg() -> RemoteConfig {
    RemoteConfig {
        connect_timeout: Duration::from_millis(1_000),
        io_timeout: Duration::from_secs(10),
        heartbeat: Duration::from_millis(100),
        backoff: Duration::from_millis(10),
        backoff_max: Duration::from_millis(100),
        max_attempts: 100,
        pipeline: 2,
    }
}

/// Bind an ephemeral loopback port and serve the operator from a thread —
/// the in-test stand-in for one `hmatc shard-worker` process. Without a
/// quota the accept loop never returns, so callers leak the handle.
fn spawn_worker(op: Arc<PlannedOperator>, exit_after: Option<u64>) -> (String, JoinHandle<Result<(), String>>) {
    let listener = bind_listener("127.0.0.1:0").expect("bind ephemeral worker port");
    let addr = listener.local_addr().expect("local addr").to_string();
    let h = std::thread::spawn(move || serve_worker(listener, op, ExecutorKind::StaticLpt, exit_after));
    (addr, h)
}

fn start_fleet(op: &Arc<PlannedOperator>, workers: usize) -> (Vec<String>, MvmServer) {
    let addrs: Vec<String> = (0..workers).map(|_| spawn_worker(op.clone(), None).0).collect();
    let server = MvmServer::start_remote(op.clone(), &addrs, BatchPolicy::default(), fast_cfg()).expect("remote fleet starts");
    (addrs, server)
}

/// Two-worker loopback fleet vs the in-process sharded server vs the
/// unsharded plan: single calls and a multi-RHS panel, all bitwise.
fn check_remote_matches_sharded(op: Arc<PlannedOperator>, tag: &str) {
    let (nr, nc) = (op.nrows(), op.ncols());
    let mut rng = Rng::new(777);
    let xs: Vec<Vec<f64>> = (0..4).map(|_| rng.vector(nc)).collect();
    let panel = DMatrix::random(nc, 3, &mut rng);

    let sharded = MvmServer::start_sharded(op.clone(), 2, ExecutorKind::StaticLpt, BatchPolicy::default()).expect("sharded");
    let want: Vec<Vec<f64>> = xs.iter().map(|x| sharded.call(x.clone()).y).collect();
    let want_panel = sharded.call_panel(panel.clone()).y;
    drop(sharded);

    let (_addrs, remote) = start_fleet(&op, 2);
    for (x, w) in xs.iter().zip(&want) {
        let got = remote.call(x.clone());
        assert_eq!(got.y.len(), nr, "{tag}: response length");
        assert_bits_eq(&got.y, w, &format!("{tag} remote vs sharded"));
        // and against the unsharded plan, the ground truth both tiers chase
        let mut flat = vec![0.0; nr];
        op.apply(1.0, x, &mut flat);
        assert_bits_eq(&got.y, &flat, &format!("{tag} remote vs unsharded"));
    }
    let got_panel = remote.call_panel(panel.clone());
    assert_eq!(got_panel.ncols, 3, "{tag}: panel columns");
    assert_bits_eq(&got_panel.y, &want_panel, &format!("{tag} remote panel"));

    // the fleet actually went over sockets: every shard shipped and
    // received bytes and completed round trips
    for (i, c) in remote.metrics.shard_counters().iter().enumerate() {
        let s = c.snapshot();
        assert!(s.net_tx > 0, "{tag}: shard {i} sent nothing");
        assert!(s.net_rx > 0, "{tag}: shard {i} received nothing");
        assert!(s.round_trips > 0, "{tag}: shard {i} completed no round trips");
    }
    let line = remote.metrics.net_summary().expect("net summary after remote serving");
    assert!(line.starts_with("net: tx "), "unexpected net summary: {line}");
    drop(remote); // must not hang
}

#[test]
fn remote_fleet_matches_in_process_sharded_bitwise_h() {
    let h0 = build_h(2, 1e-7);
    for compress in [false, true] {
        let mut h = h0.clone();
        if compress {
            h.compress(&CompressionConfig { codec: Codec::Aflp, eps: 1e-9, valr: true });
        }
        let op = Arc::new(PlannedOperator::from_h_with(Arc::new(h), ExecutorKind::StaticLpt));
        check_remote_matches_sharded(op, &format!("H compress={compress}"));
    }
}

#[test]
fn remote_fleet_matches_in_process_sharded_bitwise_uh() {
    let h0 = build_h(2, 1e-7);
    for compress in [false, true] {
        let mut uh = hmatc::uniform::build_from_h(&h0, 1e-6, hmatc::uniform::CouplingKind::Combined);
        if compress {
            uh.compress(&CompressionConfig { codec: Codec::Fpx, eps: 1e-9, valr: true });
        }
        let op = Arc::new(PlannedOperator::from_uniform_with(Arc::new(uh), ExecutorKind::StaticLpt));
        check_remote_matches_sharded(op, &format!("UH compress={compress}"));
    }
}

#[test]
fn remote_fleet_matches_in_process_sharded_bitwise_h2() {
    let h0 = build_h(2, 1e-7);
    for compress in [false, true] {
        let mut h2 = hmatc::h2::build_from_h(&h0, 1e-6);
        if compress {
            h2.compress(&CompressionConfig { codec: Codec::Aflp, eps: 1e-9, valr: true });
        }
        let op = Arc::new(PlannedOperator::from_h2_with(Arc::new(h2), ExecutorKind::StaticLpt));
        check_remote_matches_sharded(op, &format!("H2 compress={compress}"));
    }
}

/// The protocol-level client: forward and adjoint jobs against each shard
/// worker individually must match the local [`ShardPlan`] bit for bit.
#[test]
fn remote_shard_client_forward_and_adjoint_match_shard_plans() {
    let h = build_h(2, 1e-7);
    let op = Arc::new(PlannedOperator::from_h_with(Arc::new(h), ExecutorKind::StaticLpt));
    let dims = (op.nrows() as u64, op.ncols() as u64);
    let mut rng = Rng::new(99);
    let xf = DMatrix::random(op.ncols(), 2, &mut rng);
    let xa = DMatrix::random(op.nrows(), 2, &mut rng);
    for spec in row_partition(&op, 2).expect("partition") {
        let local = ShardPlan::build(&op, spec.clone(), ExecutorKind::StaticLpt);
        let (addr, _worker) = spawn_worker(op.clone(), None);
        let mut client = RemoteShardClient::connect(&addr, &spec, dims, &fast_cfg()).expect("client connects");
        for (adjoint, x) in [(false, &xf), (true, &xa)] {
            let (rows, got) = client.call(7, x, adjoint).expect("remote job");
            assert_eq!(rows, local.owned(adjoint), "shard {} owned rows", spec.index);
            let mut want = DMatrix::zeros(rows.len(), x.ncols());
            local.apply_multi_owned(adjoint, 1.0, x, None, &mut want);
            assert_bits_eq(got.data(), want.data(), &format!("shard {} adjoint={adjoint}", spec.index));
        }
    }
}

/// Hostile frames must be rejected (connection dropped, clear reason) while
/// the worker keeps serving well-formed clients — no UB, no wedge, no exit.
#[test]
fn hostile_frames_are_rejected_and_the_worker_keeps_serving() {
    let h = build_h(1, 1e-6);
    let op = Arc::new(PlannedOperator::from_h_with(Arc::new(h), ExecutorKind::StaticLpt));
    let dims = (op.nrows() as u64, op.ncols() as u64);
    let (addr, _worker) = spawn_worker(op.clone(), None);
    let spec = row_partition(&op, 1).expect("partition").remove(0);

    // a frame claiming to be 1 GiB + 1 (over MAX_FRAME)
    let huge = (hmatc::coordinator::wire::MAX_FRAME as u32 + 1).to_le_bytes().to_vec();
    // a hello frame with its checksum corrupted in the last byte
    let mut bad_sum = hmatc::coordinator::wire::encode_frame(&hmatc::coordinator::wire::Frame::Hello {
        version: hmatc::coordinator::wire::WIRE_VERSION,
        nrows: dims.0,
        ncols: dims.1,
    });
    *bad_sum.last_mut().unwrap() ^= 0xFF;
    // a coordinator from the future
    let wrong_version = hmatc::coordinator::wire::encode_frame(&hmatc::coordinator::wire::Frame::Hello {
        version: hmatc::coordinator::wire::WIRE_VERSION + 1,
        nrows: dims.0,
        ncols: dims.1,
    });
    // a frame cut off mid-body (write, then slam the connection shut)
    let truncated = {
        let full = hmatc::coordinator::wire::encode_frame(&hmatc::coordinator::wire::Frame::Ping);
        full[..full.len() - 2].to_vec()
    };
    for (what, bytes) in [("huge length", huge), ("bad checksum", bad_sum), ("wrong version", wrong_version), ("truncated", truncated)] {
        let mut s = TcpStream::connect(&addr).unwrap_or_else(|e| panic!("{what}: connect: {e}"));
        s.write_all(&bytes).unwrap_or_else(|e| panic!("{what}: write: {e}"));
        // half-close so the mid-frame cases see EOF, not a silent stall;
        // the worker must then close on us rather than hang or crash
        let _ = s.shutdown(std::net::Shutdown::Write);
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut sink = Vec::new();
        let _ = s.read_to_end(&mut sink);
    }
    // after all that abuse, a proper client is served correctly
    let mut rng = Rng::new(5);
    let x = DMatrix::random(op.ncols(), 1, &mut rng);
    let mut client = RemoteShardClient::connect(&addr, &spec, dims, &fast_cfg()).expect("client connects after abuse");
    let (rows, got) = client.call(1, &x, false).expect("job after abuse");
    let mut want = vec![0.0; op.nrows()];
    op.apply(1.0, x.col(0), &mut want);
    assert_bits_eq(got.data(), &want[rows], "post-abuse result");
}

/// Kill a worker mid-stream (job quota) and restart it on the same address:
/// the courier must reconnect with backoff, replay the in-flight job, and
/// every response must stay bitwise correct — and the reconnect shows up in
/// the per-shard network counters.
#[test]
fn killed_worker_restart_replays_in_flight_jobs() {
    let h = build_h(1, 1e-6);
    let op = Arc::new(PlannedOperator::from_h_with(Arc::new(h), ExecutorKind::StaticLpt));
    // worker 0 dies after 2 jobs; a supervisor thread restarts it on the
    // same address (SO_REUSEADDR + bind retry cover the handoff race)
    let (addr0, dying) = spawn_worker(op.clone(), Some(2));
    let respawn_op = op.clone();
    let respawn_addr = addr0.clone();
    let supervisor = std::thread::spawn(move || {
        dying.join().expect("worker thread").expect("worker exits its quota cleanly");
        let listener = bind_listener_retry(&respawn_addr, Duration::from_secs(10)).expect("rebind after quota exit");
        serve_worker(listener, respawn_op, ExecutorKind::StaticLpt, None)
    });
    let (addr1, _steady) = spawn_worker(op.clone(), None);
    let server =
        MvmServer::start_remote(op.clone(), &[addr0, addr1], BatchPolicy::default(), fast_cfg()).expect("remote fleet starts");
    let mut rng = Rng::new(4242);
    for i in 0..6 {
        let x = rng.vector(op.ncols());
        let mut want = vec![0.0; op.nrows()];
        op.apply(1.0, &x, &mut want);
        let got = server.try_call(x).unwrap_or_else(|e| panic!("call {i} through restart: {e}"));
        assert_bits_eq(&got.y, &want, &format!("call {i} through worker restart"));
    }
    let snap = server.metrics.shard_counters()[0].snapshot();
    assert!(snap.reconnects >= 1, "shard 0 must have reconnected, counters: {snap:?}");
    drop(server);
    drop(supervisor); // steady-state accept loop: leaked, not joined
}

/// The fault-injection hook on the remote tier: the courier asks the worker
/// to drop the connection before the job (a simulated crash), then replays
/// it on the reconnect — the caller sees a correct answer, not an error.
#[test]
fn injected_fault_is_replayed_transparently() {
    let h = build_h(1, 1e-6);
    let op = Arc::new(PlannedOperator::from_h_with(Arc::new(h), ExecutorKind::StaticLpt));
    let (_addrs, server) = start_fleet(&op, 2);
    let mut rng = Rng::new(11);
    let x = rng.vector(op.ncols());
    let healthy = server.try_call(x.clone()).expect("healthy call");
    server.inject_shard_fault(1);
    let replayed = server.try_call(x.clone()).expect("faulted call must replay, not fail");
    assert_bits_eq(&replayed.y, &healthy.y, "replayed response");
    let snap = server.metrics.shard_counters()[1].snapshot();
    assert!(snap.reconnects >= 1, "shard 1 must have reconnected after the crash, counters: {snap:?}");
    // and the tier keeps serving
    let again = server.try_call(x).expect("post-crash call");
    assert_bits_eq(&again.y, &healthy.y, "post-crash response");
}
