//! Coordinator (MVM server) integration: correctness under concurrency,
//! batching behaviour, metrics sanity.

use hmatc::cluster::{BlockTree, ClusterTree, StdAdmissibility};
use hmatc::coordinator::{BatchPolicy, MvmServer};
use hmatc::geometry::icosphere;
use hmatc::hmatrix::HMatrix;
use hmatc::kernelfn::{LaplaceSlp, MatrixGen};
use hmatc::lowrank::AcaOptions;
use hmatc::mvm::{mvm, MvmAlgorithm};
use hmatc::util::Rng;
use std::sync::Arc;
use std::time::Duration;

fn build(level: usize) -> Arc<HMatrix> {
    let geom = icosphere(level);
    let gen = LaplaceSlp::new(&geom);
    let ct = Arc::new(ClusterTree::build(gen.points(), 32));
    let bt = Arc::new(BlockTree::build(&ct, &ct, &StdAdmissibility::new(2.0)));
    Arc::new(HMatrix::build(&bt, &gen, &AcaOptions::with_eps(1e-6)))
}

#[test]
fn concurrent_clients_get_correct_answers() {
    let h = build(2);
    let server = Arc::new(MvmServer::start(h.clone(), BatchPolicy { max_batch: 8, linger: Duration::from_micros(500), ..BatchPolicy::default() }));
    let n = h.nrows();
    std::thread::scope(|s| {
        for c in 0..6 {
            let server = server.clone();
            let h = h.clone();
            s.spawn(move || {
                let mut rng = Rng::new(300 + c);
                for _ in 0..8 {
                    let x = rng.vector(n);
                    let resp = server.call(x.clone());
                    let mut want = vec![0.0; n];
                    mvm(1.0, &h, &x, &mut want, MvmAlgorithm::Seq);
                    for i in 0..n {
                        assert!((resp.y[i] - want[i]).abs() < 1e-9, "client {c}");
                    }
                }
            });
        }
    });
    let m = server.metrics.snapshot();
    assert_eq!(m.requests, 48);
    assert!(m.p50_latency > 0.0);
}

#[test]
fn compressed_matrix_served_identically() {
    let h = build(2);
    let mut hz = (*h).clone();
    hz.compress(&hmatc::compress::CompressionConfig::aflp(1e-9));
    let hz = Arc::new(hz);
    let s1 = MvmServer::start(h.clone(), BatchPolicy::default());
    let s2 = MvmServer::start(hz, BatchPolicy::default());
    let mut rng = Rng::new(33);
    let x = rng.vector(h.ncols());
    let r1 = s1.call(x.clone());
    let r2 = s2.call(x);
    let norm: f64 = r1.y.iter().map(|v| v * v).sum::<f64>().sqrt();
    let diff: f64 = r1.y.iter().zip(&r2.y).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
    assert!(diff < 1e-6 * norm);
}

#[test]
fn max_batch_respected() {
    let h = build(1);
    let server = Arc::new(MvmServer::start(h.clone(), BatchPolicy { max_batch: 3, linger: Duration::from_millis(30), ..BatchPolicy::default() }));
    let mut rng = Rng::new(34);
    let rxs: Vec<_> = (0..9).map(|_| server.submit(rng.vector(h.ncols()))).collect();
    for rx in rxs {
        let resp = rx.recv().unwrap().unwrap();
        assert!(resp.batch_size <= 3, "batch {}", resp.batch_size);
    }
}

#[test]
fn server_shuts_down_cleanly() {
    let h = build(1);
    let server = MvmServer::start(h.clone(), BatchPolicy::default());
    let mut rng = Rng::new(35);
    let _ = server.call(rng.vector(h.ncols()));
    drop(server); // must not hang
}
