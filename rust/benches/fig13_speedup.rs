//! Figure 13 — speedup of compressed MVM (AFLP and FPX) over uncompressed
//! MVM for H, UH and H², vs n and vs ε.
//!
//! Expected shape (paper): ≈2–3× for H, 1.5–2.5× for UH, less for H²
//! (none at the finest ε); AFLP ≥ FPX in total speedup (better ratio beats
//! cheaper decode); speedups shrink as ε→0 and grow with n.

use hmatc::bench::workloads::{Formats, Problem};
use hmatc::bench::{bench_fn, default_eps, default_levels, write_result, Table};
use hmatc::compress::{Codec, CompressionConfig};
use hmatc::mvm::{H2MvmAlgorithm, MvmAlgorithm, UniMvmAlgorithm};
use hmatc::util::args::Args;
use hmatc::util::json::Json;
use hmatc::util::Rng;

struct Speedups {
    h: f64,
    uh: f64,
    h2: f64,
}

fn measure(p: &Problem, f0: &Formats, eps: f64, codec: Codec) -> Speedups {
    let f = Formats { h: f0.h.clone(), uh: f0.uh.clone(), h2: f0.h2.clone() };
    let n = p.n();
    let mut rng = Rng::new(3);
    let x = rng.vector(n);
    let mut y = vec![0.0; n];

    let th0 = bench_fn(1, 5, 0.02, || hmatc::mvm::mvm(1.0, &f.h, &x, &mut y, MvmAlgorithm::ClusterLists)).median;
    let tu0 = bench_fn(1, 5, 0.02, || hmatc::mvm::uniform_mvm(1.0, &f.uh, &x, &mut y, UniMvmAlgorithm::RowWise)).median;
    let t20 = bench_fn(1, 5, 0.02, || hmatc::mvm::h2_mvm(1.0, &f.h2, &x, &mut y, H2MvmAlgorithm::RowWise)).median;

    let mut f = f;
    let cfg = CompressionConfig { codec, eps, valr: true };
    f.h.compress(&cfg);
    f.uh.compress(&cfg);
    f.h2.compress(&cfg);

    let th1 = bench_fn(1, 5, 0.02, || hmatc::mvm::mvm(1.0, &f.h, &x, &mut y, MvmAlgorithm::ClusterLists)).median;
    let tu1 = bench_fn(1, 5, 0.02, || hmatc::mvm::uniform_mvm(1.0, &f.uh, &x, &mut y, UniMvmAlgorithm::RowWise)).median;
    let t21 = bench_fn(1, 5, 0.02, || hmatc::mvm::h2_mvm(1.0, &f.h2, &x, &mut y, H2MvmAlgorithm::RowWise)).median;

    Speedups { h: th0 / th1, uh: tu0 / tu1, h2: t20 / t21 }
}

fn main() {
    let args = Args::from_env();
    let levels = default_levels(args.flag("large"));
    let eps = 1e-6;

    println!("\n== Fig. 13: speedup of compressed vs uncompressed MVM, vs n (eps = {eps:.0e}) ==");
    let mut t = Table::new(&["n", "codec", "H", "UH", "H2"]);
    let mut vs_n = Vec::new();
    for &level in &levels {
        let p = Problem::new(level);
        let f0 = Formats::build(&p, eps);
        for codec in [Codec::Aflp, Codec::Fpx] {
            let s = measure(&p, &f0, eps, codec);
            t.row(vec![
                p.n().to_string(),
                codec.name().into(),
                format!("{:.2}x", s.h),
                format!("{:.2}x", s.uh),
                format!("{:.2}x", s.h2),
            ]);
            vs_n.push(Json::obj(vec![
                ("n", p.n().into()),
                ("codec", codec.name().into()),
                ("h", s.h.into()),
                ("uh", s.uh.into()),
                ("h2", s.h2.into()),
            ]));
        }
    }
    t.print();

    println!("\n== Fig. 13: speedup vs eps (n fixed) ==");
    let p = Problem::new(*levels.last().unwrap());
    let mut t2 = Table::new(&["eps", "codec", "H", "UH", "H2"]);
    let mut vs_eps = Vec::new();
    for &eps in &default_eps() {
        let f0 = Formats::build(&p, eps);
        for codec in [Codec::Aflp, Codec::Fpx] {
            let s = measure(&p, &f0, eps, codec);
            t2.row(vec![
                format!("{eps:.0e}"),
                codec.name().into(),
                format!("{:.2}x", s.h),
                format!("{:.2}x", s.uh),
                format!("{:.2}x", s.h2),
            ]);
            vs_eps.push(Json::obj(vec![
                ("eps", eps.into()),
                ("codec", codec.name().into()),
                ("h", s.h.into()),
                ("uh", s.uh.into()),
                ("h2", s.h2.into()),
            ]));
        }
    }
    t2.print();

    write_result("fig13_speedup", &Json::obj(vec![("vs_n", Json::arr(vs_n)), ("vs_eps", Json::arr(vs_eps))]));
}
