//! Figure 13 — speedup of compressed MVM (AFLP and FPX) over uncompressed
//! MVM for H, UH and H², vs n and vs ε. Each format is measured through its
//! fastest recursive traversal *and* through the precomputed execution plan
//! (`hmatc::plan`), so the plan layer shows up in the speedup trajectory.
//!
//! Expected shape (paper): ≈2–3× for H, 1.5–2.5× for UH, less for H²
//! (none at the finest ε); AFLP ≥ FPX in total speedup (better ratio beats
//! cheaper decode); speedups shrink as ε→0 and grow with n.
//!
//! Each format's plan is additionally measured after measurement-driven
//! cost-model calibration (`plan calibrated` rows: `calibrate` + LPT
//! re-balancing, bitwise output-invariant), so static-vs-calibrated packing
//! lands in the speedup trajectory too.

use hmatc::bench::workloads::{Formats, Problem};
use hmatc::bench::{bench_fn, default_eps, default_levels, write_bench_json, write_result, Table};
use hmatc::compress::{Codec, CompressionConfig};
use hmatc::mvm::{H2MvmAlgorithm, MvmAlgorithm, UniMvmAlgorithm};
use hmatc::plan::{Arena, H2Plan, HPlan, UniPlan};
use hmatc::util::args::Args;
use hmatc::util::json::Json;
use hmatc::util::Rng;

struct Speedups {
    h: f64,
    h_plan: f64,
    h_plan_cal: f64,
    uh: f64,
    uh_plan: f64,
    uh_plan_cal: f64,
    h2: f64,
    h2_plan: f64,
    h2_plan_cal: f64,
}

struct Timings {
    h: f64,
    h_plan: f64,
    h_plan_cal: f64,
    uh: f64,
    uh_plan: f64,
    uh_plan_cal: f64,
    h2: f64,
    h2_plan: f64,
    h2_plan_cal: f64,
}

fn time_formats(f: &Formats, x: &[f64], y: &mut [f64]) -> Timings {
    let h_plan = HPlan::build(&f.h);
    let uh_plan = UniPlan::build(&f.uh);
    let h2_plan = H2Plan::build(&f.h2);
    // baseline plan rows honor the ambient HMATC_COSTS profile (like
    // serving) so the document's `cost_source` stamp stays truthful; unset
    // (CI) means the static byte model
    if let Some(p) = hmatc::plan::costmodel::costs_from_env() {
        h_plan.rebalance(&p);
        uh_plan.rebalance(&p);
        h2_plan.rebalance(&p);
    }
    // measurement-calibrated plans: same task lists, re-balanced packing —
    // a degenerate fit would silently leave the static packing under the
    // 'plan calibrated' columns, so fail loudly instead
    let h_cal = HPlan::build(&f.h);
    assert!(h_cal.calibrate(&f.h, 2).is_usable(), "H calibration degenerated");
    let uh_cal = UniPlan::build(&f.uh);
    assert!(uh_cal.calibrate(&f.uh, 2).is_usable(), "UH calibration degenerated");
    let h2_cal = H2Plan::build(&f.h2);
    assert!(h2_cal.calibrate(&f.h2, 2).is_usable(), "H2 calibration degenerated");
    let mut arena = Arena::new();
    Timings {
        h: bench_fn(1, 5, 0.02, || hmatc::mvm::mvm(1.0, &f.h, x, y, MvmAlgorithm::ClusterLists)).median,
        h_plan: bench_fn(1, 5, 0.02, || h_plan.execute(&f.h, 1.0, x, y, &mut arena)).median,
        h_plan_cal: bench_fn(1, 5, 0.02, || h_cal.execute(&f.h, 1.0, x, y, &mut arena)).median,
        uh: bench_fn(1, 5, 0.02, || hmatc::mvm::uniform_mvm(1.0, &f.uh, x, y, UniMvmAlgorithm::RowWise)).median,
        uh_plan: bench_fn(1, 5, 0.02, || uh_plan.execute(&f.uh, 1.0, x, y, &mut arena)).median,
        uh_plan_cal: bench_fn(1, 5, 0.02, || uh_cal.execute(&f.uh, 1.0, x, y, &mut arena)).median,
        h2: bench_fn(1, 5, 0.02, || hmatc::mvm::h2_mvm(1.0, &f.h2, x, y, H2MvmAlgorithm::RowWise)).median,
        h2_plan: bench_fn(1, 5, 0.02, || h2_plan.execute(&f.h2, 1.0, x, y, &mut arena)).median,
        h2_plan_cal: bench_fn(1, 5, 0.02, || h2_cal.execute(&f.h2, 1.0, x, y, &mut arena)).median,
    }
}

fn measure(p: &Problem, f0: &Formats, eps: f64, codec: Codec) -> Speedups {
    let n = p.n();
    let mut rng = Rng::new(3);
    let x = rng.vector(n);
    let mut y = vec![0.0; n];

    let t0 = time_formats(f0, &x, &mut y);

    let mut f = Formats { h: f0.h.clone(), uh: f0.uh.clone(), h2: f0.h2.clone() };
    let cfg = CompressionConfig { codec, eps, valr: true };
    f.h.compress(&cfg);
    f.uh.compress(&cfg);
    f.h2.compress(&cfg);

    let t1 = time_formats(&f, &x, &mut y);

    Speedups {
        h: t0.h / t1.h,
        h_plan: t0.h_plan / t1.h_plan,
        h_plan_cal: t0.h_plan_cal / t1.h_plan_cal,
        uh: t0.uh / t1.uh,
        uh_plan: t0.uh_plan / t1.uh_plan,
        uh_plan_cal: t0.uh_plan_cal / t1.uh_plan_cal,
        h2: t0.h2 / t1.h2,
        h2_plan: t0.h2_plan / t1.h2_plan,
        h2_plan_cal: t0.h2_plan_cal / t1.h2_plan_cal,
    }
}

fn row_json(n_or_eps: (&str, Json), codec: Codec, s: &Speedups) -> Json {
    Json::obj(vec![
        n_or_eps,
        ("codec", codec.name().into()),
        ("h", s.h.into()),
        ("h plan", s.h_plan.into()),
        ("h plan calibrated", s.h_plan_cal.into()),
        ("uh", s.uh.into()),
        ("uh plan", s.uh_plan.into()),
        ("uh plan calibrated", s.uh_plan_cal.into()),
        ("h2", s.h2.into()),
        ("h2 plan", s.h2_plan.into()),
        ("h2 plan calibrated", s.h2_plan_cal.into()),
    ])
}

fn main() {
    let args = Args::from_env();
    let levels = default_levels(args.flag("large"));
    let eps = 1e-6;

    println!("\n== Fig. 13: speedup of compressed vs uncompressed MVM, vs n (eps = {eps:.0e}) ==");
    let mut t = Table::new(&["n", "codec", "H", "H plan", "H plan cal", "UH", "UH plan", "UH plan cal", "H2", "H2 plan", "H2 plan cal"]);
    let mut vs_n = Vec::new();
    for &level in &levels {
        let p = Problem::new(level);
        let f0 = Formats::build(&p, eps);
        for codec in [Codec::Aflp, Codec::Fpx] {
            let s = measure(&p, &f0, eps, codec);
            t.row(vec![
                p.n().to_string(),
                codec.name().into(),
                format!("{:.2}x", s.h),
                format!("{:.2}x", s.h_plan),
                format!("{:.2}x", s.h_plan_cal),
                format!("{:.2}x", s.uh),
                format!("{:.2}x", s.uh_plan),
                format!("{:.2}x", s.uh_plan_cal),
                format!("{:.2}x", s.h2),
                format!("{:.2}x", s.h2_plan),
                format!("{:.2}x", s.h2_plan_cal),
            ]);
            vs_n.push(row_json(("n", p.n().into()), codec, &s));
        }
    }
    t.print();

    println!("\n== Fig. 13: speedup vs eps (n fixed) ==");
    let p = Problem::new(*levels.last().unwrap());
    let mut t2 = Table::new(&["eps", "codec", "H", "H plan", "H plan cal", "UH", "UH plan", "UH plan cal", "H2", "H2 plan", "H2 plan cal"]);
    let mut vs_eps = Vec::new();
    for &eps in &default_eps() {
        let f0 = Formats::build(&p, eps);
        for codec in [Codec::Aflp, Codec::Fpx] {
            let s = measure(&p, &f0, eps, codec);
            t2.row(vec![
                format!("{eps:.0e}"),
                codec.name().into(),
                format!("{:.2}x", s.h),
                format!("{:.2}x", s.h_plan),
                format!("{:.2}x", s.h_plan_cal),
                format!("{:.2}x", s.uh),
                format!("{:.2}x", s.uh_plan),
                format!("{:.2}x", s.uh_plan_cal),
                format!("{:.2}x", s.h2),
                format!("{:.2}x", s.h2_plan),
                format!("{:.2}x", s.h2_plan_cal),
            ]);
            vs_eps.push(row_json(("eps", eps.into()), codec, &s));
        }
    }
    t2.print();

    let doc = Json::obj(vec![("vs_n", Json::arr(vs_n)), ("vs_eps", Json::arr(vs_eps))]);
    write_result("fig13_speedup", &doc);
    write_bench_json("fig13", &doc);
}
