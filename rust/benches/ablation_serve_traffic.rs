//! Ablation — serving under adversarial traffic: the adaptive loop
//! (continuous per-class batching + online cost calibration,
//! `MvmServer::start_adaptive`) vs the static fixed-policy batcher, over
//! four mixes designed to defeat a fixed batch size: interleaved request
//! widths (b ∈ {1..64}), a uniform-H format mix, cold-start single-RHS
//! bursts, and the sharded scatter/gather tier (row ownership is already
//! cost-skewed across shards). Emits `BENCH_serve_traffic.json` with
//! adaptive-vs-static throughput/latency rows per mix; `--quick` is the CI
//! bench-smoke configuration.

use hmatc::bench::{write_bench_json, Table};
use hmatc::bench::workloads::Problem;
use hmatc::coordinator::{BatchPolicy, MvmServer, OnlineConfig};
use hmatc::la::DMatrix;
use hmatc::plan::{ExecutorKind, PlannedOperator};
use hmatc::util::args::Args;
use hmatc::util::json::Json;
use hmatc::util::{fmt_secs, Rng, Timer};
use std::sync::Arc;
use std::time::Duration;

/// One request of a traffic mix: `width` right-hand sides, submitted after
/// an optional client-side gap (bursts use 0 inside, a long pause between).
#[derive(Clone, Copy)]
struct Job {
    width: usize,
    gap_us: u64,
}

/// Interleaved widths: singles threaded between ever-wider panels, the
/// worst case for any fixed `max_batch`.
fn mix_interleaved(n_jobs: usize) -> Vec<Job> {
    const WIDTHS: [usize; 8] = [1, 1, 4, 1, 16, 2, 8, 32];
    (0..n_jobs).map(|i| Job { width: WIDTHS[i % WIDTHS.len()], gap_us: 0 }).collect()
}

/// Cold-start bursts: all singles, fired in back-to-back volleys with idle
/// gaps between them — the profile window starts empty on every server.
fn mix_bursts(n_jobs: usize) -> Vec<Job> {
    (0..n_jobs).map(|i| Job { width: 1, gap_us: if i > 0 && i % 16 == 0 { 400 } else { 0 } }).collect()
}

/// Drive one server with a mix; returns (wall seconds, served RHS columns).
fn run(server: &MvmServer, n: usize, jobs: &[Job], seed: u64) -> (f64, usize) {
    let mut rng = Rng::new(seed);
    let t = Timer::start();
    let mut rxs = Vec::with_capacity(jobs.len());
    for j in jobs {
        if j.gap_us > 0 {
            std::thread::sleep(Duration::from_micros(j.gap_us));
        }
        if j.width == 1 {
            rxs.push(server.submit(rng.vector(n)));
        } else {
            rxs.push(server.submit_panel(DMatrix::random(n, j.width, &mut rng)));
        }
    }
    let mut cols = 0usize;
    for rx in rxs {
        let resp = rx.recv().expect("server alive").expect("serve ok");
        cols += resp.ncols;
    }
    (t.elapsed(), cols)
}

/// Run one (mix, mode) cell and return its result row.
#[allow(clippy::too_many_arguments)]
fn cell(mix: &str, mode: &str, server: &MvmServer, n: usize, jobs: &[Job], seed: u64, table: &mut Table) -> Json {
    let (wall, cols) = run(server, n, jobs, seed);
    let m = server.metrics.snapshot();
    let st = server.online_status();
    table.row(vec![
        mix.to_string(),
        mode.to_string(),
        cols.to_string(),
        format!("{:.0} col/s", cols as f64 / wall),
        fmt_secs(m.p50_latency),
        fmt_secs(m.p99_latency),
        format!("{:.2}", m.avg_batch),
        st.as_ref().map_or("-".to_string(), |s| format!("{}/{}", s.refits, s.swaps)),
    ]);
    Json::obj(vec![
        ("mix", Json::Str(mix.to_string())),
        ("mode", Json::Str(mode.to_string())),
        ("requests", (jobs.len() as f64).into()),
        ("cols", (cols as f64).into()),
        ("wall_s", wall.into()),
        ("throughput_cols_per_s", (cols as f64 / wall).into()),
        ("p50_latency_s", m.p50_latency.into()),
        ("p99_latency_s", m.p99_latency.into()),
        ("batches", (m.batches as f64).into()),
        ("avg_batch", m.avg_batch.into()),
        ("refits", st.as_ref().map_or(Json::Null, |s| (s.refits as f64).into())),
        ("swaps", st.as_ref().map_or(Json::Null, |s| (s.swaps as f64).into())),
    ])
}

fn main() {
    let args = Args::from_env();
    let quick = args.flag("quick");
    let level = args.num_or("level", if quick { 2usize } else { 3 });
    let eps = 1e-6;
    let p = Problem::new(level);
    let h = p.build_h(eps);
    let n = p.n();
    let njobs = if quick { 24usize } else { 96 };
    let policy = BatchPolicy::default();
    // small min_samples so the bootstrap fit (cost_source → online) lands
    // within the mix even in --quick
    let cfg = OnlineConfig { min_samples: 16, ..Default::default() };

    let h_op = Arc::new(PlannedOperator::from_h(Arc::new(h.clone())));
    let uh = hmatc::uniform::build_from_h(&h, eps, hmatc::uniform::CouplingKind::Combined);
    let uh_op = Arc::new(PlannedOperator::from_uniform(Arc::new(uh)));

    println!("\n== Ablation: adaptive vs static serving under adversarial traffic (n = {n}, {njobs} jobs/mix) ==");
    let mut table = Table::new(&["mix", "mode", "cols", "throughput", "p50", "p99", "avg batch", "refits/swaps"]);
    let mut rows = Vec::new();

    // mix 1: interleaved widths on H — fresh server per cell (cold start)
    let jobs = mix_interleaved(njobs);
    let server = MvmServer::start(h_op.clone(), policy);
    rows.push(cell("interleaved_widths", "static", &server, n, &jobs, 21, &mut table));
    drop(server);
    let server = MvmServer::start_adaptive(h_op.clone(), policy, cfg.clone());
    rows.push(cell("interleaved_widths", "adaptive", &server, n, &jobs, 21, &mut table));
    drop(server);

    // mix 2: the same widths through the uniform-H format
    let server = MvmServer::start(uh_op.clone(), policy);
    rows.push(cell("format_mix_uh", "static", &server, n, &jobs, 22, &mut table));
    drop(server);
    let server = MvmServer::start_adaptive(uh_op, policy, cfg.clone());
    rows.push(cell("format_mix_uh", "adaptive", &server, n, &jobs, 22, &mut table));
    drop(server);

    // mix 3: cold-start single-RHS bursts
    let jobs = mix_bursts(njobs * 2);
    let server = MvmServer::start(h_op.clone(), policy);
    rows.push(cell("cold_start_bursts", "static", &server, n, &jobs, 23, &mut table));
    drop(server);
    let server = MvmServer::start_adaptive(h_op.clone(), policy, cfg.clone());
    rows.push(cell("cold_start_bursts", "adaptive", &server, n, &jobs, 23, &mut table));
    drop(server);

    // mix 4: interleaved widths through the sharded scatter/gather tier
    // (shard row ownership is cost-skewed by construction)
    let jobs = mix_interleaved(njobs);
    let kind = ExecutorKind::StaticLpt;
    let server = MvmServer::start_sharded(h_op.clone(), 2, kind, policy).expect("sharded server");
    rows.push(cell("sharded_skew", "static", &server, n, &jobs, 24, &mut table));
    drop(server);
    let server = MvmServer::start_sharded_adaptive(h_op, 2, kind, policy, cfg).expect("sharded adaptive server");
    rows.push(cell("sharded_skew", "adaptive", &server, n, &jobs, 24, &mut table));
    drop(server);

    table.print();
    let doc = Json::obj(vec![
        ("quick", Json::Bool(quick)),
        ("n", (n as f64).into()),
        ("rows", Json::arr(rows)),
    ]);
    write_bench_json("serve_traffic", &doc);
    println!("rows written to BENCH_serve_traffic.json");
}
