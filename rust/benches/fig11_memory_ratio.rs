//! Figure 11 — memory of H and UH relative to H², uncompressed vs
//! compressed, vs size (left) and accuracy (right).
//!
//! Expected shape (paper): compression shrinks the H²-advantage; compressed
//! UH can even beat compressed H² at small n; asymptotically H² wins.

use hmatc::bench::workloads::{Formats, Problem};
use hmatc::bench::{default_eps, default_levels, write_result, Table};
use hmatc::compress::CompressionConfig;
use hmatc::util::args::Args;
use hmatc::util::json::Json;

fn row(f: Formats, eps: f64) -> (f64, f64, f64, f64) {
    let h2_0 = f.h2.byte_size() as f64;
    let rh_unc = f.h.byte_size() as f64 / h2_0;
    let ru_unc = f.uh.byte_size() as f64 / h2_0;
    let mut f = f;
    let cfg = CompressionConfig::aflp(eps);
    f.h.compress(&cfg);
    f.uh.compress(&cfg);
    f.h2.compress(&cfg);
    let h2_z = f.h2.byte_size() as f64;
    (rh_unc, ru_unc, f.h.byte_size() as f64 / h2_z, f.uh.byte_size() as f64 / h2_z)
}

fn main() {
    let args = Args::from_env();
    let levels = default_levels(args.flag("large"));
    let eps = 1e-6;

    println!("\n== Fig. 11 (left): memory relative to H² vs n (eps = {eps:.0e}) ==");
    let mut t = Table::new(&["n", "H/H2 unc", "UH/H2 unc", "H/H2 cmp", "UH/H2 cmp"]);
    let mut vs_n = Vec::new();
    for &level in &levels {
        let p = Problem::new(level);
        let (a, b, c, d) = row(Formats::build(&p, eps), eps);
        t.row(vec![p.n().to_string(), format!("{a:.2}"), format!("{b:.2}"), format!("{c:.2}"), format!("{d:.2}")]);
        vs_n.push(Json::obj(vec![
            ("n", p.n().into()),
            ("h_unc", a.into()),
            ("uh_unc", b.into()),
            ("h_cmp", c.into()),
            ("uh_cmp", d.into()),
        ]));
    }
    t.print();

    println!("\n== Fig. 11 (right): memory relative to H² vs eps ==");
    let p = Problem::new(*levels.last().unwrap());
    let mut t2 = Table::new(&["eps", "H/H2 unc", "UH/H2 unc", "H/H2 cmp", "UH/H2 cmp"]);
    let mut vs_eps = Vec::new();
    for &eps in &default_eps() {
        let (a, b, c, d) = row(Formats::build(&p, eps), eps);
        t2.row(vec![format!("{eps:.0e}"), format!("{a:.2}"), format!("{b:.2}"), format!("{c:.2}"), format!("{d:.2}")]);
        vs_eps.push(Json::obj(vec![
            ("eps", eps.into()),
            ("h_unc", a.into()),
            ("uh_unc", b.into()),
            ("h_cmp", c.into()),
            ("uh_cmp", d.into()),
        ]));
    }
    t2.print();

    write_result("fig11_memory_ratio", &Json::obj(vec![("vs_n", Json::arr(vs_n)), ("vs_eps", Json::arr(vs_eps))]));
}
