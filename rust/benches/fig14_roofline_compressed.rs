//! Figure 14 — roofline for the AFLP-compressed MVM: performance improves in
//! absolute terms but sits further from the (now smaller-footprint) roof due
//! to decompression overhead (paper: ≈60 % of peak instead of ≈80 %).

use hmatc::bench::workloads::{Formats, Problem};
use hmatc::bench::{bench_fn, measure_peak_bandwidth, write_result, Table};
use hmatc::compress::CompressionConfig;
use hmatc::mvm::{H2MvmAlgorithm, MvmAlgorithm, UniMvmAlgorithm};
use hmatc::util::args::Args;
use hmatc::util::json::Json;
use hmatc::util::Rng;

fn main() {
    let args = Args::from_env();
    let level = args.num_or("level", 4usize);
    let eps = 1e-6;
    println!("measuring peak bandwidth (STREAM triad)…");
    let peak = measure_peak_bandwidth();
    println!("peak ≈ {peak:.2} GB/s\n");

    let p = Problem::new(level);
    let mut f = Formats::build(&p, eps);
    let cfg = CompressionConfig::aflp(eps);
    f.h.compress(&cfg);
    f.uh.compress(&cfg);
    f.h2.compress(&cfg);

    let n = p.n();
    let mut rng = Rng::new(5);
    let x = rng.vector(n);
    let mut y = vec![0.0; n];

    let rh = bench_fn(1, 7, 0.05, || hmatc::mvm::mvm(1.0, &f.h, &x, &mut y, MvmAlgorithm::ClusterLists));
    let ru = bench_fn(1, 7, 0.05, || hmatc::mvm::uniform_mvm(1.0, &f.uh, &x, &mut y, UniMvmAlgorithm::RowWise));
    let r2 = bench_fn(1, 7, 0.05, || hmatc::mvm::h2_mvm(1.0, &f.h2, &x, &mut y, H2MvmAlgorithm::RowWise));

    let mut t = Table::new(&["format", "median", "achieved GB/s", "% of peak", "paper"]);
    let mut doc = Vec::new();
    for (name, r, bytes, paper) in [
        ("H zAFLP", &rh, f.h.byte_size(), "~60%"),
        ("UH zAFLP", &ru, f.uh.byte_size(), "~60%"),
        ("H2 zAFLP", &r2, f.h2.byte_size(), "~60%"),
    ] {
        let gbs = bytes as f64 / r.median / 1e9;
        t.row(vec![
            name.into(),
            hmatc::util::fmt_secs(r.median),
            format!("{gbs:.2}"),
            format!("{:.0}%", 100.0 * gbs / peak),
            paper.into(),
        ]);
        doc.push(Json::obj(vec![
            ("format", name.into()),
            ("median", r.median.into()),
            ("achieved_gbs", gbs.into()),
            ("fraction_of_peak", (gbs / peak).into()),
        ]));
    }
    t.print();
    write_result("fig14_roofline_compressed", &Json::obj(vec![("peak_gbs", peak.into()), ("points", Json::arr(doc))]));
}
