//! Figure 10 — compression ratios (uncompressed/compressed bytes) of AFLP
//! and FPX for H, UH and H², vs size (left) and accuracy (right).
//!
//! Expected shape (paper): H best, then UH, then H²; ratios grow with n for
//! H/UH, stay flat for H²; AFLP compresses better than FPX; ratios shrink
//! as ε gets finer.

use hmatc::bench::workloads::{Formats, Problem};
use hmatc::bench::{default_eps, default_levels, write_result, Table};
use hmatc::compress::{Codec, CompressionConfig};
use hmatc::util::args::Args;
use hmatc::util::json::Json;

fn ratios(f: &Formats, codec: Codec, eps: f64) -> (f64, f64, f64) {
    let (h0, u0, t0) = (f.h.byte_size() as f64, f.uh.byte_size() as f64, f.h2.byte_size() as f64);
    let mut f = Formats { h: f.h.clone(), uh: f.uh.clone(), h2: f.h2.clone() };
    let cfg = CompressionConfig { codec, eps, valr: true };
    f.h.compress(&cfg);
    f.uh.compress(&cfg);
    f.h2.compress(&cfg);
    (h0 / f.h.byte_size() as f64, u0 / f.uh.byte_size() as f64, t0 / f.h2.byte_size() as f64)
}

fn main() {
    let args = Args::from_env();
    let levels = default_levels(args.flag("large"));
    let eps = 1e-6;

    println!("\n== Fig. 10 (left): compression ratio vs n (eps = {eps:.0e}) ==");
    let mut t = Table::new(&["n", "codec", "H", "UH", "H2"]);
    let mut vs_n = Vec::new();
    for &level in &levels {
        let p = Problem::new(level);
        let f = Formats::build(&p, eps);
        for codec in [Codec::Aflp, Codec::Fpx] {
            let (rh, ru, r2) = ratios(&f, codec, eps);
            t.row(vec![
                p.n().to_string(),
                codec.name().into(),
                format!("{rh:.2}x"),
                format!("{ru:.2}x"),
                format!("{r2:.2}x"),
            ]);
            vs_n.push(Json::obj(vec![
                ("n", p.n().into()),
                ("codec", codec.name().into()),
                ("h", rh.into()),
                ("uh", ru.into()),
                ("h2", r2.into()),
            ]));
        }
    }
    t.print();

    println!("\n== Fig. 10 (right): compression ratio vs eps ==");
    let p = Problem::new(*levels.last().unwrap());
    let mut t2 = Table::new(&["eps", "codec", "H", "UH", "H2"]);
    let mut vs_eps = Vec::new();
    for &eps in &default_eps() {
        let f = Formats::build(&p, eps);
        for codec in [Codec::Aflp, Codec::Fpx] {
            let (rh, ru, r2) = ratios(&f, codec, eps);
            t2.row(vec![
                format!("{eps:.0e}"),
                codec.name().into(),
                format!("{rh:.2}x"),
                format!("{ru:.2}x"),
                format!("{r2:.2}x"),
            ]);
            vs_eps.push(Json::obj(vec![
                ("eps", eps.into()),
                ("codec", codec.name().into()),
                ("h", rh.into()),
                ("uh", ru.into()),
                ("h2", r2.into()),
            ]));
        }
    }
    t2.print();

    write_result("fig10_compression_rates", &Json::obj(vec![("vs_n", Json::arr(vs_n)), ("vs_eps", Json::arr(vs_eps))]));
}
