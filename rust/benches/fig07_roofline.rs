//! Figure 7 — roofline for the (uncompressed) H-, UH- and H²-MVM, plus the
//! batched multi-RHS sweep. The single-vector algorithms are bandwidth
//! limited (paper: ≈79 % / 78 % / 82 % of peak); batching b right-hand sides
//! into one gemm-shaped plan traversal multiplies the arithmetic per matrix
//! byte by ~b, which is exactly the paper's Fig. 7 argument for raising
//! arithmetic intensity. We measure peak with a STREAM triad and report both
//! achieved bandwidth fraction and per-b GFLOP/s + bytes touched
//! (compressed and uncompressed), emitting `BENCH_fig07.json`.
//!
//! `--quick` shrinks the problem and sampling so CI can smoke-run this bench.

use hmatc::bench::workloads::{Formats, Problem};
use hmatc::bench::{bench_fn, measure_peak_bandwidth, roofline_point, write_bench_json, write_result, Table};
use hmatc::compress::CompressionConfig;
use hmatc::la::DMatrix;
use hmatc::mvm::{H2MvmAlgorithm, MvmAlgorithm, UniMvmAlgorithm};
use hmatc::plan::{HOperator, PlannedOperator};
use hmatc::util::args::Args;
use hmatc::util::json::Json;
use hmatc::util::Rng;
use std::sync::Arc;

/// flop estimate: 2 flops per stored (logical FP64) matrix coefficient.
fn flops_for(bytes: usize) -> f64 {
    2.0 * bytes as f64 / 8.0
}

fn main() {
    let args = Args::from_env();
    let quick = args.flag("quick");
    let level = args.num_or("level", if quick { 2usize } else { 4 });
    let (warm, samples, min_secs) = if quick { (0, 2, 0.002) } else { (1, 7, 0.05) };
    let eps = 1e-6;
    println!("measuring peak bandwidth (STREAM triad)…");
    let peak = measure_peak_bandwidth();
    println!("peak ≈ {peak:.2} GB/s\n");

    let p = Problem::new(level);
    let f = Formats::build(&p, eps);
    let n = p.n();
    let mut rng = Rng::new(1);
    let x = rng.vector(n);
    let mut y = vec![0.0; n];

    let mut t = Table::new(&["format", "median", "achieved GB/s", "% of peak", "paper"]);
    let mut doc = Vec::new();
    let cases: Vec<(&str, f64, usize, &str)> = {
        let rh = bench_fn(warm, samples, min_secs, || hmatc::mvm::mvm(1.0, &f.h, &x, &mut y, MvmAlgorithm::ClusterLists));
        let ru = bench_fn(warm, samples, min_secs, || {
            hmatc::mvm::uniform_mvm(1.0, &f.uh, &x, &mut y, UniMvmAlgorithm::RowWise)
        });
        let r2 = bench_fn(warm, samples, min_secs, || hmatc::mvm::h2_mvm(1.0, &f.h2, &x, &mut y, H2MvmAlgorithm::RowWise));
        vec![
            ("H (Alg 3)", rh.median, f.h.byte_size(), "79%"),
            ("UH (Alg 5)", ru.median, f.uh.byte_size(), "78%"),
            ("H2 (Alg 7)", r2.median, f.h2.byte_size(), "82%"),
        ]
    };
    for (name, median, bytes, paper) in cases {
        let pt = roofline_point(median, flops_for(bytes), bytes as f64, peak);
        let frac = bytes as f64 / median / 1e9 / peak;
        t.row(vec![
            name.into(),
            hmatc::util::fmt_secs(median),
            format!("{:.2}", bytes as f64 / median / 1e9),
            format!("{:.0}%", 100.0 * frac),
            paper.into(),
        ]);
        doc.push(Json::obj(vec![
            ("format", name.into()),
            ("median", median.into()),
            ("achieved_gbs", (bytes as f64 / median / 1e9).into()),
            ("fraction_of_peak", frac.into()),
            ("intensity", pt.intensity.into()),
        ]));
    }
    t.print();

    // ---- batched multi-RHS sweep (gemm-shaped plan schedules) ----
    let coeffs = f.h.byte_size() as f64 / 8.0; // logical FP64 coefficients
    let mut hz = f.h.clone();
    hz.compress(&CompressionConfig::aflp(eps));
    let ops: Vec<(&str, PlannedOperator)> = vec![
        ("H fp64", PlannedOperator::from_h(Arc::new(f.h.clone()))),
        ("H aflp", PlannedOperator::from_h(Arc::new(hz))),
    ];
    let bs = args.list_or("batch", &[1usize, 2, 4, 8, 16]);
    let mut bt = Table::new(&["operator", "b", "median", "GFLOP/s", "bytes touched", "GB/s (matrix)"]);
    let mut brows = Vec::new();
    for (name, op) in &ops {
        for &b in &bs {
            let xm = DMatrix::random(n, b, &mut rng);
            let mut ym = DMatrix::zeros(n, b);
            let r = bench_fn(warm, samples, min_secs, || op.apply_multi(1.0, &xm, &mut ym));
            let flops = 2.0 * coeffs * b as f64;
            let bytes_touched = op.byte_size() as f64 + 8.0 * (2 * n * b) as f64;
            let gflops = flops / r.median / 1e9;
            bt.row(vec![
                (*name).into(),
                format!("{b}"),
                hmatc::util::fmt_secs(r.median),
                format!("{gflops:.2}"),
                hmatc::util::fmt_bytes(bytes_touched as usize),
                format!("{:.2}", op.byte_size() as f64 / r.median / 1e9),
            ]);
            brows.push(Json::obj(vec![
                ("operator", (*name).into()),
                ("b", (b as f64).into()),
                ("median", r.median.into()),
                ("gflops", gflops.into()),
                ("bytes_touched", bytes_touched.into()),
                ("matrix_gbs", (op.byte_size() as f64 / r.median / 1e9).into()),
            ]));
        }
    }
    println!();
    bt.print();

    let out = Json::obj(vec![
        ("peak_gbs", peak.into()),
        ("n", n.into()),
        ("quick", quick.into()),
        ("points", Json::arr(doc)),
        ("batched", Json::arr(brows)),
    ]);
    write_result("fig07_roofline", &out);
    write_bench_json("fig07", &out);
}
