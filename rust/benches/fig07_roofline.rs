//! Figure 7 — roofline for the (uncompressed) H-, UH- and H²-MVM: the
//! algorithms are bandwidth limited; the paper reports ≈79 % / 78 % / 82 %
//! of peak. We measure peak with a STREAM triad and report achieved
//! bandwidth fraction at the kernels' arithmetic intensity.

use hmatc::bench::workloads::{Formats, Problem};
use hmatc::bench::{bench_fn, measure_peak_bandwidth, roofline_point, write_result, Table};
use hmatc::mvm::{H2MvmAlgorithm, MvmAlgorithm, UniMvmAlgorithm};
use hmatc::util::args::Args;
use hmatc::util::json::Json;
use hmatc::util::Rng;

/// flop estimate: 2 flops per stored matrix coefficient touched.
fn flops_for(bytes: usize) -> f64 {
    2.0 * bytes as f64 / 8.0
}

fn main() {
    let args = Args::from_env();
    let level = args.num_or("level", 4usize);
    let eps = 1e-6;
    println!("measuring peak bandwidth (STREAM triad)…");
    let peak = measure_peak_bandwidth();
    println!("peak ≈ {peak:.2} GB/s\n");

    let p = Problem::new(level);
    let f = Formats::build(&p, eps);
    let n = p.n();
    let mut rng = Rng::new(1);
    let x = rng.vector(n);
    let mut y = vec![0.0; n];

    let mut t = Table::new(&["format", "median", "achieved GB/s", "% of peak", "paper"]);
    let mut doc = Vec::new();
    let cases: Vec<(&str, f64, usize, &str)> = {
        let rh = bench_fn(1, 7, 0.05, || hmatc::mvm::mvm(1.0, &f.h, &x, &mut y, MvmAlgorithm::ClusterLists));
        let ru = bench_fn(1, 7, 0.05, || hmatc::mvm::uniform_mvm(1.0, &f.uh, &x, &mut y, UniMvmAlgorithm::RowWise));
        let r2 = bench_fn(1, 7, 0.05, || hmatc::mvm::h2_mvm(1.0, &f.h2, &x, &mut y, H2MvmAlgorithm::RowWise));
        vec![
            ("H (Alg 3)", rh.median, f.h.byte_size(), "79%"),
            ("UH (Alg 5)", ru.median, f.uh.byte_size(), "78%"),
            ("H2 (Alg 7)", r2.median, f.h2.byte_size(), "82%"),
        ]
    };
    for (name, median, bytes, paper) in cases {
        let pt = roofline_point(median, flops_for(bytes), bytes as f64, peak);
        let frac = bytes as f64 / median / 1e9 / peak;
        t.row(vec![
            name.into(),
            hmatc::util::fmt_secs(median),
            format!("{:.2}", bytes as f64 / median / 1e9),
            format!("{:.0}%", 100.0 * frac),
            paper.into(),
        ]);
        doc.push(Json::obj(vec![
            ("format", name.into()),
            ("median", median.into()),
            ("achieved_gbs", (bytes as f64 / median / 1e9).into()),
            ("fraction_of_peak", frac.into()),
            ("intensity", pt.intensity.into()),
        ]));
    }
    t.print();
    write_result("fig07_roofline", &Json::obj(vec![("peak_gbs", peak.into()), ("points", Json::arr(doc))]));
}
