//! Figure 9 — error of AFLP-compressed H, UH and H² matrices vs the
//! uncompressed reference H-matrix, for a sweep of accuracies ε.
//!
//! Expected shape (paper): all formats closely follow the line error ≈ ε.

use hmatc::bench::workloads::{Formats, Problem};
use hmatc::bench::{write_result, Table};
use hmatc::compress::CompressionConfig;
use hmatc::hmatrix::norms::rel_spectral_error;
use hmatc::mvm::{h2_mvm, mvm, uniform_mvm, H2MvmAlgorithm, MvmAlgorithm, UniMvmAlgorithm};
use hmatc::util::args::Args;
use hmatc::util::json::Json;

fn main() {
    let args = Args::from_env();
    let level = args.num_or("level", 3usize);
    let p = Problem::new(level);
    let n = p.n();

    println!("\n== Fig. 9: rel. error of AFLP-compressed formats vs uncompressed H (n = {n}) ==");
    let mut t = Table::new(&["eps", "H", "UH", "H2"]);
    let mut doc = Vec::new();
    for &eps in &[1e-4, 1e-6, 1e-8] {
        let f = Formats::build(&p, eps);
        // reference: uncompressed H
        let href = f.h.clone();
        let mut fh = f.h;
        let mut fu = f.uh;
        let mut f2 = f.h2;
        let cfg = CompressionConfig::aflp(eps);
        fh.compress(&cfg);
        fu.compress(&cfg);
        f2.compress(&cfg);

        let eh = rel_spectral_error(
            n,
            |x, y| mvm(1.0, &fh, x, y, MvmAlgorithm::Seq),
            |x, y| mvm(1.0, &href, x, y, MvmAlgorithm::Seq),
            30,
            11,
        );
        let eu = rel_spectral_error(
            n,
            |x, y| uniform_mvm(1.0, &fu, x, y, UniMvmAlgorithm::RowWise),
            |x, y| mvm(1.0, &href, x, y, MvmAlgorithm::Seq),
            30,
            12,
        );
        let e2 = rel_spectral_error(
            n,
            |x, y| h2_mvm(1.0, &f2, x, y, H2MvmAlgorithm::RowWise),
            |x, y| mvm(1.0, &href, x, y, MvmAlgorithm::Seq),
            30,
            13,
        );
        t.row(vec![format!("{eps:.0e}"), format!("{eh:.2e}"), format!("{eu:.2e}"), format!("{e2:.2e}")]);
        doc.push(Json::obj(vec![
            ("eps", eps.into()),
            ("h", eh.into()),
            ("uh", eu.into()),
            ("h2", e2.into()),
        ]));
        // sanity for the harness: errors must track eps within 2 orders
        assert!(eh < 100.0 * eps && eu < 100.0 * eps && e2 < 100.0 * eps, "error does not track eps");
    }
    t.print();
    write_result("fig09_error", &Json::arr(doc));
}
