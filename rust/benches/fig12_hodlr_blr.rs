//! Figure 12 — memory of uncompressed and compressed HODLR and BLR formats
//! (left) and their compression ratios (right).
//!
//! Expected shape (paper): HODLR is smaller uncompressed, but the compressed
//! sizes of HODLR and BLR are essentially identical.

use hmatc::bench::{write_result, Table};
use hmatc::cluster::{BlockTree, ClusterTree, OffDiagAdmissibility};
use hmatc::compress::CompressionConfig;
use hmatc::geometry::icosphere;
use hmatc::hmatrix::HMatrix;
use hmatc::kernelfn::{LaplaceSlp, MatrixGen};
use hmatc::lowrank::AcaOptions;
use hmatc::util::args::Args;
use hmatc::util::json::Json;
use hmatc::util::fmt_bytes;
use std::sync::Arc;

fn main() {
    let args = Args::from_env();
    let level = args.num_or("level", 3usize);
    let eps = args.num_or("eps", 1e-4f64);
    let geom = icosphere(level);
    let gen = LaplaceSlp::new(&geom);
    let n = gen.len();

    // HODLR: deep binary tree + off-diagonal admissibility
    let ct_h = Arc::new(ClusterTree::build(gen.points(), 64));
    let bt_h = Arc::new(BlockTree::build(&ct_h, &ct_h, &OffDiagAdmissibility));
    let mut hodlr = HMatrix::build(&bt_h, &gen, &AcaOptions::with_eps(eps));

    // BLR: flat clustering + off-diagonal admissibility
    let ct_b = Arc::new(ClusterTree::build_blr(gen.points(), 256));
    let bt_b = Arc::new(BlockTree::build(&ct_b, &ct_b, &OffDiagAdmissibility));
    let mut blr = HMatrix::build(&bt_b, &gen, &AcaOptions::with_eps(eps));

    let h0 = hodlr.byte_size();
    let b0 = blr.byte_size();
    let cfg = CompressionConfig::aflp(eps);
    hodlr.compress(&cfg);
    blr.compress(&cfg);
    let hz = hodlr.byte_size();
    let bz = blr.byte_size();

    println!("\n== Fig. 12: HODLR vs BLR (n = {n}, eps = {eps:.0e}) ==");
    let mut t = Table::new(&["format", "uncompressed", "compressed", "ratio"]);
    t.row(vec!["HODLR".into(), fmt_bytes(h0), fmt_bytes(hz), format!("{:.2}x", h0 as f64 / hz as f64)]);
    t.row(vec!["BLR".into(), fmt_bytes(b0), fmt_bytes(bz), format!("{:.2}x", b0 as f64 / bz as f64)]);
    t.print();
    println!("compressed HODLR / compressed BLR = {:.2} (paper: ≈1)", hz as f64 / bz as f64);

    write_result(
        "fig12_hodlr_blr",
        &Json::obj(vec![
            ("n", n.into()),
            ("eps", eps.into()),
            ("hodlr_unc", h0.into()),
            ("hodlr_cmp", hz.into()),
            ("blr_unc", b0.into()),
            ("blr_cmp", bz.into()),
        ]),
    );
}
