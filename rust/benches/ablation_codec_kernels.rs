//! Ablation — compressed gemv kernel variants (Remark 4.1 / §4.3):
//!
//! 1. raw decode throughput per codec × byte width, runtime-dispatched SIMD
//!    vs forced-scalar (pins the "no special `RUSTFLAGS` needed" claim: the
//!    dispatched build must match the old `target-feature=+avx2` build);
//! 2. `zgemv` kernel sweep across byte widths: **fused** decode–FMA vs the
//!    legacy **blockwise** stack-buffer scheme vs **direct** per-entry
//!    random access (Algorithm 8 as printed);
//! 3. compressed H-MVM plan execution with fused vs blockwise kernels — the
//!    end-to-end number the fused path exists for.
//!
//! Emits `BENCH_ablation_codec.json` (stamped with `executor` + `threads`
//! via [`hmatc::bench::write_bench_json`]) plus the `bench_results/` archive
//! copy. `--quick` shrinks sizes and sampling so CI can smoke-run it.

use hmatc::bench::workloads::Problem;
use hmatc::bench::{bench_fn, write_bench_json, write_result, Table};
use hmatc::compress::dispatch::{self, KernelMode, SimdLevel};
use hmatc::compress::{Blob, Codec, CompressionConfig};
use hmatc::hmatrix::{HMatrix, ZDense};
use hmatc::la::DMatrix;
use hmatc::mvm::{zgemv_blockwise, zgemv_direct, zgemv_fused};
use hmatc::plan::{Arena, HPlan};
use hmatc::util::args::Args;
use hmatc::util::json::Json;
use hmatc::util::Rng;

/// MVM flops of an H-matrix (2mn per dense block, 2k(m+n) per low-rank; a
/// rank-0 admissible block executes ~nothing and is counted as 0).
fn h_flops(h: &HMatrix) -> f64 {
    let mut fl = 0.0;
    for b in h.blocks.iter().flatten() {
        let (m, n, k) = (b.nrows() as f64, b.ncols() as f64, b.rank() as f64);
        fl += if b.is_lowrank() { 2.0 * k * (m + n) } else { 2.0 * m * n };
    }
    fl
}

fn main() {
    let args = Args::from_env();
    let quick = args.flag("quick");
    let (warm, samples, min_secs) = if quick { (0, 2, 0.002) } else { (1, 5, 0.02) };
    let mut rng = Rng::new(8);

    println!("simd: {} | codec kernels: {}", dispatch::simd_name(), dispatch::kernel_mode_name());

    // -- 1. raw decode throughput across byte widths, dispatched vs scalar --
    println!("\n== Ablation: raw decode throughput (GB/s of decoded f64) ==");
    let n_decode = if quick { 1 << 16 } else { 1 << 20 };
    let data = {
        let mut v = vec![0.0; n_decode];
        rng.fill_normal(&mut v);
        v
    };
    let mut out = vec![0.0; data.len()];
    let mut t = Table::new(&["codec", "eps", "bytes/val", "GB/s (dispatched)", "GB/s (scalar)", "simd gain"]);
    let mut decode_doc = Vec::new();
    for codec in [Codec::Aflp, Codec::Fpx] {
        for &eps in &[1e-2, 1e-4, 1e-8, 1e-12] {
            let blob = Blob::compress(codec, &data, eps);
            let r = bench_fn(warm, samples, min_secs, || blob.decompress_into(&mut out));
            dispatch::force_simd(Some(SimdLevel::Scalar));
            let rs = bench_fn(warm, samples, min_secs, || blob.decompress_into(&mut out));
            dispatch::force_simd(None);
            let gbs = (data.len() * 8) as f64 / r.median / 1e9;
            let gbs_s = (data.len() * 8) as f64 / rs.median / 1e9;
            t.row(vec![
                codec.name().into(),
                format!("{eps:.0e}"),
                blob.bytes_per_value().to_string(),
                format!("{gbs:.2}"),
                format!("{gbs_s:.2}"),
                format!("{:.2}x", gbs / gbs_s),
            ]);
            decode_doc.push(Json::obj(vec![
                ("codec", codec.name().into()),
                ("eps", eps.into()),
                ("bytes_per_value", blob.bytes_per_value().into()),
                ("decode_gbs", gbs.into()),
                ("decode_gbs_scalar", gbs_s.into()),
                ("simd", dispatch::simd_name().into()),
            ]));
        }
    }
    t.print();

    // -- 2. zgemv kernel sweep: fused vs blockwise vs direct, per width --
    println!("\n== Ablation: zgemv fused vs blockwise vs direct ==");
    let shapes: &[(usize, usize)] = if quick { &[(256, 128)] } else { &[(64, 64), (256, 256), (1024, 256)] };
    let mut t2 = Table::new(&["codec", "shape", "bytes/val", "direct", "blockwise", "fused", "fused GF/s", "fused/blockwise"]);
    let mut zgemv_doc = Vec::new();
    for &(m, n) in shapes {
        let mat = DMatrix::random(m, n, &mut rng);
        let x = rng.vector(n);
        let mut y = vec![0.0; m];
        let flops = 2.0 * m as f64 * n as f64;
        for codec in [Codec::Aflp, Codec::Fpx] {
            for &eps in &[1e-2, 1e-6, 1e-10] {
                let z = ZDense::compress(&mat, codec, eps);
                let rd = bench_fn(warm, samples, min_secs, || zgemv_direct(1.0, &z, &x, &mut y));
                let rb = bench_fn(warm, samples, min_secs, || zgemv_blockwise(1.0, &z, &x, &mut y));
                let rf = bench_fn(warm, samples, min_secs, || zgemv_fused(1.0, &z, &x, &mut y));
                t2.row(vec![
                    codec.name().into(),
                    format!("{m}x{n}"),
                    z.blob.bytes_per_value().to_string(),
                    hmatc::util::fmt_secs(rd.median),
                    hmatc::util::fmt_secs(rb.median),
                    hmatc::util::fmt_secs(rf.median),
                    format!("{:.2}", flops / rf.median / 1e9),
                    format!("{:.2}x", rb.median / rf.median),
                ]);
                zgemv_doc.push(Json::obj(vec![
                    ("codec", codec.name().into()),
                    ("m", m.into()),
                    ("n", n.into()),
                    ("eps", eps.into()),
                    ("bytes_per_value", z.blob.bytes_per_value().into()),
                    ("direct", rd.median.into()),
                    ("blockwise", rb.median.into()),
                    ("fused", rf.median.into()),
                    ("fused_gflops", (flops / rf.median / 1e9).into()),
                    ("blockwise_gflops", (flops / rb.median / 1e9).into()),
                    ("fused_speedup", (rb.median / rf.median).into()),
                ]));
            }
        }
    }
    t2.print();

    // -- 3. compressed H-MVM plan tasks: fused vs blockwise end to end --
    println!("\n== Ablation: compressed H-MVM (plan executor), fused vs blockwise ==");
    let level = if quick { 2 } else { 3 };
    let eps = 1e-6; // the paper's default block accuracy
    let p = Problem::new(level);
    let mut t3 = Table::new(&["codec", "n", "blockwise GF/s", "fused GF/s", "fused/blockwise"]);
    let mut hmvm_doc = Vec::new();
    for codec in [Codec::Aflp, Codec::Fpx] {
        let mut h = p.build_h(eps);
        h.compress(&CompressionConfig { codec, eps, valr: true });
        let flops = h_flops(&h);
        let plan = HPlan::build(&h);
        let mut arena = Arena::new();
        let nn = p.n();
        let x = rng.vector(nn);
        let mut y = vec![0.0; nn];
        dispatch::set_kernel_mode(Some(KernelMode::Blockwise));
        let rb = bench_fn(warm, samples, min_secs, || plan.execute(&h, 1.0, &x, &mut y, &mut arena));
        dispatch::set_kernel_mode(Some(KernelMode::Fused));
        let rf = bench_fn(warm, samples, min_secs, || plan.execute(&h, 1.0, &x, &mut y, &mut arena));
        dispatch::set_kernel_mode(None);
        let gf_b = flops / rb.median / 1e9;
        let gf_f = flops / rf.median / 1e9;
        t3.row(vec![
            codec.name().into(),
            nn.to_string(),
            format!("{gf_b:.2}"),
            format!("{gf_f:.2}"),
            format!("{:.2}x", rb.median / rf.median),
        ]);
        hmvm_doc.push(Json::obj(vec![
            ("codec", codec.name().into()),
            ("n", nn.into()),
            ("eps", eps.into()),
            ("flops", flops.into()),
            ("blockwise", rb.median.into()),
            ("fused", rf.median.into()),
            ("blockwise_gflops", gf_b.into()),
            ("fused_gflops", gf_f.into()),
            ("fused_speedup", (rb.median / rf.median).into()),
        ]));
    }
    t3.print();

    let doc = Json::obj(vec![
        ("quick", quick.into()),
        ("simd", dispatch::simd_name().into()),
        ("kernels", dispatch::kernels_label().into()),
        ("decode", Json::arr(decode_doc)),
        ("zgemv", Json::arr(zgemv_doc)),
        ("hmvm", Json::arr(hmvm_doc)),
    ]);
    write_result("ablation_codec_kernels", &doc);
    write_bench_json("ablation_codec", &doc);
}
