//! Ablation — compressed gemv kernel variants (Remark 4.1 / §4.3): direct
//! per-entry decode (Algorithm 8 as printed) vs the 64-entry blockwise
//! scheme, for AFLP and FPX, across block shapes.
//!
//! Also measures raw decode throughput per codec: the paper reports FPX
//! decode up to 50 % faster than AFLP (byte shift vs FP multiply-add).

use hmatc::bench::{bench_fn, write_result, Table};
use hmatc::compress::{Blob, Codec};
use hmatc::hmatrix::ZDense;
use hmatc::la::DMatrix;
use hmatc::mvm::{zgemv_blocked, zgemv_direct};
use hmatc::util::json::Json;
use hmatc::util::Rng;

fn main() {
    let mut rng = Rng::new(8);
    let eps = 1e-6;

    println!("\n== Ablation: raw decode throughput (GB/s of decoded f64) ==");
    let data = {
        let mut v = vec![0.0; 1 << 20];
        rng.fill_normal(&mut v);
        v
    };
    let mut out = vec![0.0; data.len()];
    let mut t = Table::new(&["codec", "bytes/val", "decode GB/s (output)"]);
    let mut doc = Vec::new();
    for codec in [Codec::Aflp, Codec::Fpx] {
        let blob = Blob::compress(codec, &data, eps);
        let r = bench_fn(1, 5, 0.05, || blob.decompress_into(&mut out));
        let gbs = (data.len() * 8) as f64 / r.median / 1e9;
        t.row(vec![codec.name().into(), blob.bytes_per_value().to_string(), format!("{gbs:.2}")]);
        doc.push(Json::obj(vec![
            ("codec", codec.name().into()),
            ("bytes_per_value", blob.bytes_per_value().into()),
            ("decode_gbs", gbs.into()),
        ]));
    }
    t.print();

    println!("\n== Ablation: zgemv direct vs blockwise ==");
    let mut t2 = Table::new(&["codec", "shape", "direct", "blocked", "blocked speedup"]);
    let mut doc2 = Vec::new();
    for (m, n) in [(64usize, 64usize), (256, 256), (1024, 256)] {
        let mat = DMatrix::random(m, n, &mut rng);
        let x = rng.vector(n);
        let mut y = vec![0.0; m];
        for codec in [Codec::Aflp, Codec::Fpx] {
            let z = ZDense::compress(&mat, codec, eps);
            let rd = bench_fn(1, 5, 0.02, || zgemv_direct(1.0, &z, &x, &mut y));
            let rb = bench_fn(1, 5, 0.02, || zgemv_blocked(1.0, &z, &x, &mut y));
            t2.row(vec![
                codec.name().into(),
                format!("{m}x{n}"),
                hmatc::util::fmt_secs(rd.median),
                hmatc::util::fmt_secs(rb.median),
                format!("{:.2}x", rd.median / rb.median),
            ]);
            doc2.push(Json::obj(vec![
                ("codec", codec.name().into()),
                ("m", m.into()),
                ("n", n.into()),
                ("direct", rd.median.into()),
                ("blocked", rb.median.into()),
            ]));
        }
    }
    t2.print();

    write_result("ablation_codec_kernels", &Json::obj(vec![("decode", Json::arr(doc)), ("zgemv", Json::arr(doc2))]));
}
