//! Ablation — the cross-process shard fleet vs in-process serving:
//!
//! The same closed-loop request stream runs against three tiers built from
//! one compressed H operator: the single-worker server, the in-process
//! sharded scatter/gather tier, and the remote fleet (two `serve_worker`
//! loops behind loopback TCP couriers — same wire protocol, heartbeats and
//! reconnect machinery as a real deployment, minus the physical network).
//! Every tier's responses are **bitwise-verified** against the unsharded
//! plan in-bench, and the remote rows carry the courier network counters
//! (bytes shipped, round trips) so the serialization overhead is visible
//! next to the throughput it buys. Emits `BENCH_ablation_remote.json` plus
//! the `bench_results/` archive copy. `--quick` shrinks the problem and the
//! request count so CI can smoke-run it.

use hmatc::bench::workloads::Problem;
use hmatc::bench::{write_bench_json, write_result, Table};
use hmatc::compress::{Codec, CompressionConfig};
use hmatc::coordinator::{bind_listener, serve_worker, BatchPolicy, MvmServer, RemoteConfig};
use hmatc::plan::{ExecutorKind, HOperator, PlannedOperator};
use hmatc::util::json::Json;
use hmatc::util::{fmt_bytes, fmt_secs, Rng, Timer};
use std::sync::Arc;

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(x.to_bits() == y.to_bits(), "{what}: entry {i}: {x:e} vs {y:e}");
    }
}

/// One worker thread per fleet member, each on its own ephemeral loopback
/// port — the in-bench stand-in for `hmatc shard-worker` processes.
fn spawn_fleet(op: &Arc<PlannedOperator>, workers: usize) -> Vec<String> {
    (0..workers)
        .map(|_| {
            let listener = bind_listener("127.0.0.1:0").expect("bind worker port");
            let addr = listener.local_addr().expect("local addr").to_string();
            let op = op.clone();
            std::thread::spawn(move || serve_worker(listener, op, ExecutorKind::StaticLpt, None));
            addr
        })
        .collect()
}

fn main() {
    let args = hmatc::util::args::Args::from_env();
    let quick = args.flag("quick");
    let level = if quick { 2 } else { 3 };
    let nreq = if quick { 32usize } else { 256 };
    let workers = 2usize;

    let p = Problem::new(level);
    let mut h = p.build_h(1e-6);
    h.compress(&CompressionConfig { codec: Codec::Aflp, eps: 1e-9, valr: true });
    let n = p.n();
    let op = Arc::new(PlannedOperator::from_h_with(Arc::new(h), ExecutorKind::StaticLpt));
    println!("operator: H compressed, n = {n}, {}", fmt_bytes(op.byte_size()));

    // the request stream and its ground truth, shared by every tier
    let mut rng = Rng::new(31);
    let xs: Vec<Vec<f64>> = (0..nreq).map(|_| rng.vector(n)).collect();
    let want: Vec<Vec<f64>> = xs
        .iter()
        .map(|x| {
            let mut y = vec![0.0; n];
            op.apply(1.0, x, &mut y);
            y
        })
        .collect();

    let addrs = spawn_fleet(&op, workers);
    let tiers: Vec<(&str, MvmServer)> = vec![
        ("single", MvmServer::start(op.clone(), BatchPolicy::default())),
        (
            "sharded:2",
            MvmServer::start_sharded(op.clone(), workers, ExecutorKind::StaticLpt, BatchPolicy::default()).expect("sharded tier"),
        ),
        (
            "remote:2",
            MvmServer::start_remote(op.clone(), &addrs, BatchPolicy::default(), RemoteConfig::default()).expect("remote fleet"),
        ),
    ];

    println!("\n== Ablation: remote fleet vs in-process serving (n={n}, {nreq} requests) ==");
    let mut t = Table::new(&["tier", "wall", "req/s", "vs single", "net tx", "net rx"]);
    let mut rows = Vec::new();
    let mut single_rps = None;
    for (name, server) in &tiers {
        let timer = Timer::start();
        for (x, w) in xs.iter().zip(&want) {
            let got = server.call(x.clone());
            assert_bits_eq(&got.y, w, &format!("{name} response"));
        }
        let wall = timer.elapsed();
        let rps = nreq as f64 / wall;
        let speedup = match single_rps {
            None => {
                single_rps = Some(rps);
                1.0
            }
            Some(base) => rps / base,
        };
        let (tx, rx, trips) = server.metrics.shard_counters().iter().fold((0u64, 0u64, 0u64), |acc, c| {
            let s = c.snapshot();
            (acc.0 + s.net_tx, acc.1 + s.net_rx, acc.2 + s.round_trips)
        });
        t.row(vec![
            (*name).to_string(),
            fmt_secs(wall),
            format!("{rps:.1}"),
            format!("{speedup:.2}x"),
            if tx > 0 { fmt_bytes(tx as usize) } else { "-".to_string() },
            if rx > 0 { fmt_bytes(rx as usize) } else { "-".to_string() },
        ]);
        rows.push(Json::obj(vec![
            ("tier", (*name).into()),
            ("n", n.into()),
            ("requests", nreq.into()),
            ("wall_seconds", wall.into()),
            ("req_per_sec", rps.into()),
            ("speedup_vs_single", speedup.into()),
            ("net_tx_bytes", (tx as f64).into()),
            ("net_rx_bytes", (rx as f64).into()),
            ("net_round_trips", (trips as f64).into()),
            ("bitwise_verified", true.into()),
        ]));
    }
    t.print();
    println!("\nall tiers bitwise-verified against the unsharded plan");

    let doc = Json::obj(vec![("quick", quick.into()), ("workers", workers.into()), ("rows", Json::arr(rows))]);
    write_result("ablation_remote", &doc);
    write_bench_json("ablation_remote", &doc);
}
