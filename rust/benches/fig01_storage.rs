//! Figure 1 — matrix storage (bytes per DoF) for H, UH and H² formats,
//! vs problem size (left) and vs accuracy (right).
//!
//! Expected shape (paper): H grows fastest with n; UH grows slower; H² is
//! ~constant per DoF. All grow as ε decreases.

use hmatc::bench::workloads::{Formats, Problem};
use hmatc::bench::{default_eps, default_levels, write_result, Table};
use hmatc::util::args::Args;
use hmatc::util::json::Json;

fn main() {
    let args = Args::from_env();
    let levels = default_levels(args.flag("large"));
    let eps_fixed = 1e-6;

    println!("\n== Fig. 1 (left): storage per DoF vs n (eps = {eps_fixed:.0e}) ==");
    let mut t = Table::new(&["n", "H B/dof", "UH B/dof", "H2 B/dof"]);
    let mut series = Vec::new();
    for &level in &levels {
        let p = Problem::new(level);
        let f = Formats::build(&p, eps_fixed);
        t.row(vec![
            p.n().to_string(),
            format!("{:.1}", f.h.bytes_per_dof()),
            format!("{:.1}", f.uh.bytes_per_dof()),
            format!("{:.1}", f.h2.bytes_per_dof()),
        ]);
        series.push(Json::obj(vec![
            ("n", p.n().into()),
            ("h", f.h.bytes_per_dof().into()),
            ("uh", f.uh.bytes_per_dof().into()),
            ("h2", f.h2.bytes_per_dof().into()),
        ]));
    }
    t.print();

    println!("\n== Fig. 1 (right): storage per DoF vs eps (n fixed) ==");
    let level = *levels.last().unwrap();
    let p = Problem::new(level);
    let mut t2 = Table::new(&["eps", "H B/dof", "UH B/dof", "H2 B/dof"]);
    let mut series_eps = Vec::new();
    for &eps in &default_eps() {
        let f = Formats::build(&p, eps);
        t2.row(vec![
            format!("{eps:.0e}"),
            format!("{:.1}", f.h.bytes_per_dof()),
            format!("{:.1}", f.uh.bytes_per_dof()),
            format!("{:.1}", f.h2.bytes_per_dof()),
        ]);
        series_eps.push(Json::obj(vec![
            ("eps", eps.into()),
            ("h", f.h.bytes_per_dof().into()),
            ("uh", f.uh.bytes_per_dof().into()),
            ("h2", f.h2.bytes_per_dof().into()),
        ]));
    }
    t2.print();

    write_result(
        "fig01_storage",
        &Json::obj(vec![("vs_n", Json::arr(series)), ("vs_eps", Json::arr(series_eps))]),
    );
}
