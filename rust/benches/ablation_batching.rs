//! Ablation — coordinator batching: multi-RHS MVM amortizes matrix loads
//! over the batch, raising arithmetic intensity ∝ batch size. Reports
//! per-request time vs batch size for uncompressed and compressed H.

use hmatc::bench::workloads::Problem;
use hmatc::bench::{bench_fn, write_result, Table};
use hmatc::compress::CompressionConfig;
use hmatc::la::DMatrix;
use hmatc::mvm::h_mvm_multi;
use hmatc::util::args::Args;
use hmatc::util::json::Json;
use hmatc::util::Rng;

fn main() {
    let args = Args::from_env();
    let level = args.num_or("level", 4usize);
    let eps = 1e-6;
    let p = Problem::new(level);
    let h = p.build_h(eps);
    let mut hz = h.clone();
    hz.compress(&CompressionConfig::aflp(eps));
    let n = p.n();
    let mut rng = Rng::new(4);

    println!("\n== Ablation: multi-RHS batching (n = {n}, eps = {eps:.0e}) ==");
    let mut t = Table::new(&["batch", "t/req (unc)", "t/req (aflp)", "unc speedup vs b=1"]);
    let mut doc = Vec::new();
    let mut base = 0.0;
    for &b in &[1usize, 2, 4, 8, 16] {
        let x = DMatrix::random(n, b, &mut rng);
        let mut y = DMatrix::zeros(n, b);
        let r = bench_fn(1, 5, 0.02, || h_mvm_multi(1.0, &h, &x, &mut y));
        let rz = bench_fn(1, 5, 0.02, || h_mvm_multi(1.0, &hz, &x, &mut y));
        let per_req = r.median / b as f64;
        let per_req_z = rz.median / b as f64;
        if b == 1 {
            base = per_req;
        }
        t.row(vec![
            b.to_string(),
            hmatc::util::fmt_secs(per_req),
            hmatc::util::fmt_secs(per_req_z),
            format!("{:.2}x", base / per_req),
        ]);
        doc.push(Json::obj(vec![
            ("batch", b.into()),
            ("per_req_unc", per_req.into()),
            ("per_req_aflp", per_req_z.into()),
        ]));
    }
    t.print();
    write_result("ablation_batching", &Json::arr(doc));
}
