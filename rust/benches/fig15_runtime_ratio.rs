//! Figure 15 — MVM runtime of H and UH relative to H², uncompressed vs
//! AFLP-compressed, vs n and vs ε.
//!
//! Expected shape (paper): compression shrinks the H² performance advantage;
//! compressed UH comes very close to compressed H².

use hmatc::bench::workloads::{Formats, Problem};
use hmatc::bench::{bench_fn, default_eps, default_levels, write_result, Table};
use hmatc::compress::CompressionConfig;
use hmatc::mvm::{H2MvmAlgorithm, MvmAlgorithm, UniMvmAlgorithm};
use hmatc::util::args::Args;
use hmatc::util::json::Json;
use hmatc::util::Rng;

fn measure(p: &Problem, eps: f64) -> (f64, f64, f64, f64) {
    let f = Formats::build(p, eps);
    let n = p.n();
    let mut rng = Rng::new(6);
    let x = rng.vector(n);
    let mut y = vec![0.0; n];
    let th0 = bench_fn(1, 5, 0.02, || hmatc::mvm::mvm(1.0, &f.h, &x, &mut y, MvmAlgorithm::ClusterLists)).median;
    let tu0 = bench_fn(1, 5, 0.02, || hmatc::mvm::uniform_mvm(1.0, &f.uh, &x, &mut y, UniMvmAlgorithm::RowWise)).median;
    let t20 = bench_fn(1, 5, 0.02, || hmatc::mvm::h2_mvm(1.0, &f.h2, &x, &mut y, H2MvmAlgorithm::RowWise)).median;
    let mut f = f;
    let cfg = CompressionConfig::aflp(eps);
    f.h.compress(&cfg);
    f.uh.compress(&cfg);
    f.h2.compress(&cfg);
    let th1 = bench_fn(1, 5, 0.02, || hmatc::mvm::mvm(1.0, &f.h, &x, &mut y, MvmAlgorithm::ClusterLists)).median;
    let tu1 = bench_fn(1, 5, 0.02, || hmatc::mvm::uniform_mvm(1.0, &f.uh, &x, &mut y, UniMvmAlgorithm::RowWise)).median;
    let t21 = bench_fn(1, 5, 0.02, || hmatc::mvm::h2_mvm(1.0, &f.h2, &x, &mut y, H2MvmAlgorithm::RowWise)).median;
    (th0 / t20, tu0 / t20, th1 / t21, tu1 / t21)
}

fn main() {
    let args = Args::from_env();
    let levels = default_levels(args.flag("large"));
    let eps = 1e-6;

    println!("\n== Fig. 15: MVM time relative to H², vs n (eps = {eps:.0e}) ==");
    let mut t = Table::new(&["n", "H/H2 unc", "UH/H2 unc", "H/H2 cmp", "UH/H2 cmp"]);
    let mut vs_n = Vec::new();
    for &level in &levels {
        let p = Problem::new(level);
        let (a, b, c, d) = measure(&p, eps);
        t.row(vec![p.n().to_string(), format!("{a:.2}"), format!("{b:.2}"), format!("{c:.2}"), format!("{d:.2}")]);
        vs_n.push(Json::obj(vec![
            ("n", p.n().into()),
            ("h_unc", a.into()),
            ("uh_unc", b.into()),
            ("h_cmp", c.into()),
            ("uh_cmp", d.into()),
        ]));
    }
    t.print();

    println!("\n== Fig. 15: MVM time relative to H², vs eps ==");
    let p = Problem::new(*levels.last().unwrap());
    let mut t2 = Table::new(&["eps", "H/H2 unc", "UH/H2 unc", "H/H2 cmp", "UH/H2 cmp"]);
    let mut vs_eps = Vec::new();
    for &eps in &default_eps() {
        let (a, b, c, d) = measure(&p, eps);
        t2.row(vec![format!("{eps:.0e}"), format!("{a:.2}"), format!("{b:.2}"), format!("{c:.2}"), format!("{d:.2}")]);
        vs_eps.push(Json::obj(vec![
            ("eps", eps.into()),
            ("h_unc", a.into()),
            ("uh_unc", b.into()),
            ("h_cmp", c.into()),
            ("uh_cmp", d.into()),
        ]));
    }
    t2.print();

    write_result("fig15_runtime_ratio", &Json::obj(vec![("vs_n", Json::arr(vs_n)), ("vs_eps", Json::arr(vs_eps))]));
}
