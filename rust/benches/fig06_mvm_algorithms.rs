//! Figure 6 — runtime of the MVM algorithm variants for H (left), UH
//! (center) and H² (right), vs n (eps fixed) and vs eps (n fixed).
//!
//! Expected shape (paper, on a many-core machine): "cluster lists" ≈
//! "stacked" ≈ "chunks" fastest; "thread local" slower (reduction overhead);
//! for UH "row wise" best; for H² "row wise" ≥ "mutex". On this single-core
//! sandbox the ordering degenerates to per-algorithm bookkeeping overhead —
//! the reduction overhead of "thread local" and the lock overhead of
//! mutex/atomic variants remain visible.

use hmatc::bench::workloads::{Formats, Problem};
use hmatc::bench::{bench_fn, default_eps, default_levels, write_bench_json, write_result, Table};
use hmatc::mvm::{H2MvmAlgorithm, MvmAlgorithm, UniMvmAlgorithm};
use hmatc::plan::{Arena, H2Plan, HPlan, UniPlan};
use hmatc::util::args::Args;
use hmatc::util::json::Json;
use hmatc::util::Rng;

fn main() {
    let args = Args::from_env();
    let levels = default_levels(args.flag("large"));
    let eps = 1e-6;
    let mut out = Vec::new();

    for &level in &levels {
        let p = Problem::new(level);
        let f = Formats::build(&p, eps);
        let n = p.n();
        let mut rng = Rng::new(1);
        let x = rng.vector(n);
        let mut y = vec![0.0; n];

        println!("\n== Fig. 6: n = {n}, eps = {eps:.0e} ==");
        let mut t = Table::new(&["format", "algorithm", "median", "GB/s"]);
        let mut doc = vec![("n", Json::from(n))];

        // precomputed layouts/plans are built once (like the paper's setup) —
        // the enum dispatch in `mvm(..)` would rebuild them per product
        let stacked = hmatc::mvm::hmvm::StackedH::new(&f.h);
        let h_plan = HPlan::build(&f.h);
        let uh_plan = UniPlan::build(&f.uh);
        let h2_plan = H2Plan::build(&f.h2);
        let mut arena = Arena::new();
        for algo in MvmAlgorithm::all() {
            let r = match algo {
                MvmAlgorithm::Stacked => bench_fn(1, 5, 0.02, || hmatc::mvm::hmvm::stacked_with(&stacked, 1.0, &f.h, &x, &mut y)),
                MvmAlgorithm::Plan => bench_fn(1, 5, 0.02, || h_plan.execute(&f.h, 1.0, &x, &mut y, &mut arena)),
                _ => bench_fn(1, 5, 0.02, || hmatc::mvm::mvm(1.0, &f.h, &x, &mut y, algo)),
            };
            t.row(vec![
                "H".into(),
                algo.name().into(),
                hmatc::util::fmt_secs(r.median),
                format!("{:.2}", f.h.byte_size() as f64 / r.median / 1e9),
            ]);
            doc.push((algo.name(), r.median.into()));
        }
        for algo in UniMvmAlgorithm::all() {
            let r = match algo {
                UniMvmAlgorithm::Plan => bench_fn(1, 5, 0.02, || uh_plan.execute(&f.uh, 1.0, &x, &mut y, &mut arena)),
                _ => bench_fn(1, 5, 0.02, || hmatc::mvm::uniform_mvm(1.0, &f.uh, &x, &mut y, algo)),
            };
            t.row(vec![
                "UH".into(),
                algo.name().into(),
                hmatc::util::fmt_secs(r.median),
                format!("{:.2}", f.uh.byte_size() as f64 / r.median / 1e9),
            ]);
            doc.push(match algo {
                UniMvmAlgorithm::Mutex => ("uh mutex", r.median.into()),
                UniMvmAlgorithm::RowWise => ("uh row wise", r.median.into()),
                UniMvmAlgorithm::SepCoupling => ("uh sep coupling", r.median.into()),
                UniMvmAlgorithm::Plan => ("uh plan", r.median.into()),
            });
        }
        for algo in H2MvmAlgorithm::all() {
            let r = match algo {
                H2MvmAlgorithm::Plan => bench_fn(1, 5, 0.02, || h2_plan.execute(&f.h2, 1.0, &x, &mut y, &mut arena)),
                _ => bench_fn(1, 5, 0.02, || hmatc::mvm::h2_mvm(1.0, &f.h2, &x, &mut y, algo)),
            };
            t.row(vec![
                "H2".into(),
                algo.name().into(),
                hmatc::util::fmt_secs(r.median),
                format!("{:.2}", f.h2.byte_size() as f64 / r.median / 1e9),
            ]);
            doc.push(match algo {
                H2MvmAlgorithm::Mutex => ("h2 mutex", r.median.into()),
                H2MvmAlgorithm::RowWise => ("h2 row wise", r.median.into()),
                H2MvmAlgorithm::Plan => ("h2 plan", r.median.into()),
            });
        }
        t.print();
        out.push(Json::obj(doc));
    }

    // vs eps at the largest default size
    let p = Problem::new(*levels.last().unwrap());
    let mut eps_out = Vec::new();
    for &e in &default_eps() {
        let f = Formats::build(&p, e);
        let n = p.n();
        let mut rng = Rng::new(2);
        let x = rng.vector(n);
        let mut y = vec![0.0; n];
        let h_plan = HPlan::build(&f.h);
        let mut arena = Arena::new();
        let rh = bench_fn(1, 5, 0.02, || hmatc::mvm::mvm(1.0, &f.h, &x, &mut y, MvmAlgorithm::ClusterLists));
        let rp = bench_fn(1, 5, 0.02, || h_plan.execute(&f.h, 1.0, &x, &mut y, &mut arena));
        let ru = bench_fn(1, 5, 0.02, || hmatc::mvm::uniform_mvm(1.0, &f.uh, &x, &mut y, UniMvmAlgorithm::RowWise));
        let r2 = bench_fn(1, 5, 0.02, || hmatc::mvm::h2_mvm(1.0, &f.h2, &x, &mut y, H2MvmAlgorithm::RowWise));
        println!(
            "eps {e:.0e}: H {} | H plan {} | UH {} | H2 {}",
            hmatc::util::fmt_secs(rh.median),
            hmatc::util::fmt_secs(rp.median),
            hmatc::util::fmt_secs(ru.median),
            hmatc::util::fmt_secs(r2.median)
        );
        eps_out.push(Json::obj(vec![
            ("eps", e.into()),
            ("h", rh.median.into()),
            ("h plan", rp.median.into()),
            ("uh", ru.median.into()),
            ("h2", r2.median.into()),
        ]));
    }

    let doc = Json::obj(vec![("vs_n", Json::arr(out)), ("vs_eps", Json::arr(eps_out))]);
    write_result("fig06_mvm_algorithms", &doc);
    write_bench_json("fig06", &doc);
}
