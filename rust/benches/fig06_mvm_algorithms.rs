//! Figure 6 — runtime of the MVM algorithm variants for H (left), UH
//! (center) and H² (right), vs n (eps fixed) and vs eps (n fixed).
//!
//! Expected shape (paper, on a many-core machine): "cluster lists" ≈
//! "stacked" ≈ "chunks" fastest; "thread local" slower (reduction overhead);
//! for UH "row wise" best; for H² "row wise" ≥ "mutex". On this single-core
//! sandbox the ordering degenerates to per-algorithm bookkeeping overhead —
//! the reduction overhead of "thread local" and the lock overhead of
//! mutex/atomic variants remain visible.
//!
//! The plan rows are emitted **once per execution backend** (`plan`,
//! `plan steal`, `plan sharded:2`) on the same matrix, so the LPT-vs-stealing
//! comparison lands in `BENCH_fig06.json` directly. Each backend additionally
//! gets a **`plan calibrated`** row: the same plan after
//! measurement-driven cost-model calibration (`HPlan::calibrate` + LPT
//! re-balancing), bitwise-verified against the static row's output before
//! benching — so static-vs-calibrated GFLOP/s per executor lands in the JSON.
//! A **`plan sharded-coord:2`** row runs the same H operator through a 2-way
//! row partition of the sharded serving tier (shard-by-shard `ShardPlan`
//! execution + owned-row reassembly), bitwise-verified against the unsharded
//! plan. `--quick` restricts to the smallest size and skips the eps sweep
//! (CI smoke).

use hmatc::bench::workloads::{Formats, Problem};
use hmatc::bench::{bench_fn, default_eps, default_levels, write_bench_json, write_result, Table};
use hmatc::compress::{Codec, CompressionConfig};
use hmatc::mvm::{H2MvmAlgorithm, MvmAlgorithm, UniMvmAlgorithm};
use hmatc::plan::{row_partition, Arena, ExecutorKind, H2Plan, HOperator, HPlan, PlannedOperator, ShardPlan, UniPlan};
use hmatc::store::HotCache;
use hmatc::util::args::Args;
use hmatc::util::json::Json;
use hmatc::util::Rng;

/// The backends compared: the LPT baseline, work stealing, two sub-pools.
fn kinds() -> [ExecutorKind; 3] {
    ExecutorKind::all(2)
}

/// Row/key label for a plan row: the baseline keeps the historical "plan"
/// key so the perf trajectory stays continuous.
fn plan_label(kind: ExecutorKind) -> String {
    match kind {
        ExecutorKind::StaticLpt => "plan".to_string(),
        other => format!("plan {other}"),
    }
}

/// Row/key label for a calibrated plan row.
fn cal_label(kind: ExecutorKind) -> String {
    match kind {
        ExecutorKind::StaticLpt => "plan calibrated".to_string(),
        other => format!("plan calibrated {other}"),
    }
}

/// Calibrated re-balancing only re-partitions the task lists, so its output
/// must reproduce the static packing's output bit for bit; a divergence is a
/// scheduler bug and aborts the bench.
fn assert_bitwise(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(x.to_bits() == y.to_bits(), "{what}: row {i}: calibrated {x:e} vs static {y:e}");
    }
}

/// Append a table row and the matching JSON key (`<fmt-prefix><name>`).
fn push_row(t: &mut Table, doc: &mut Vec<(String, Json)>, fmt: &str, prefix: &str, name: &str, bytes: usize, median: f64) {
    t.row(vec![fmt.into(), name.into(), hmatc::util::fmt_secs(median), format!("{:.2}", bytes as f64 / median / 1e9)]);
    doc.push((format!("{prefix}{name}"), median.into()));
}

fn main() {
    let args = Args::from_env();
    let quick = args.flag("quick");
    let levels = if quick { vec![2] } else { default_levels(args.flag("large")) };
    let eps = 1e-6;
    let mut out = Vec::new();

    for &level in &levels {
        let p = Problem::new(level);
        let f = Formats::build(&p, eps);
        let n = p.n();
        let mut rng = Rng::new(1);
        let x = rng.vector(n);
        let mut y = vec![0.0; n];

        println!("\n== Fig. 6: n = {n}, eps = {eps:.0e} ==");
        let mut t = Table::new(&["format", "algorithm", "median", "GB/s"]);
        let mut doc: Vec<(String, Json)> = vec![("n".to_string(), Json::from(n))];

        // precomputed layouts/plans are built once (like the paper's setup) —
        // the enum dispatch in `mvm(..)` would rebuild them per product.
        // One plan per execution backend: schedules are packed for it.
        let stacked = hmatc::mvm::hmvm::StackedH::new(&f.h);
        let h_plans: Vec<(ExecutorKind, HPlan)> = kinds().iter().map(|&k| (k, HPlan::build_with(&f.h, k.build()))).collect();
        let uh_plans: Vec<(ExecutorKind, UniPlan)> = kinds().iter().map(|&k| (k, UniPlan::build_with(&f.uh, k.build()))).collect();
        let h2_plans: Vec<(ExecutorKind, H2Plan)> = kinds().iter().map(|&k| (k, H2Plan::build_with(&f.h2, k.build()))).collect();
        // the baseline plan rows honor the ambient HMATC_COSTS profile —
        // exactly like serving — so the document-level `cost_source` stamp
        // describes what these rows actually ran on; with the variable
        // unset (CI) they stay on the static byte model
        if let Some(p) = hmatc::plan::costmodel::costs_from_env() {
            for (_, plan) in &h_plans {
                plan.rebalance(&p);
            }
            for (_, plan) in &uh_plans {
                plan.rebalance(&p);
            }
            for (_, plan) in &h2_plans {
                plan.rebalance(&p);
            }
        }
        // the same plans after measurement-driven cost-model calibration
        let cal_rounds = if quick { 2 } else { 3 };
        // a degenerate fit would make the 'plan calibrated' label a lie
        // (rebalance ignores unusable profiles) — fail loudly instead of
        // recording static timings as calibrated data
        let h_cal: Vec<(ExecutorKind, HPlan)> = kinds()
            .iter()
            .map(|&k| {
                let plan = HPlan::build_with(&f.h, k.build());
                assert!(plan.calibrate(&f.h, cal_rounds).is_usable(), "H calibration degenerated [{k}]");
                (k, plan)
            })
            .collect();
        let uh_cal: Vec<(ExecutorKind, UniPlan)> = kinds()
            .iter()
            .map(|&k| {
                let plan = UniPlan::build_with(&f.uh, k.build());
                assert!(plan.calibrate(&f.uh, cal_rounds).is_usable(), "UH calibration degenerated [{k}]");
                (k, plan)
            })
            .collect();
        let h2_cal: Vec<(ExecutorKind, H2Plan)> = kinds()
            .iter()
            .map(|&k| {
                let plan = H2Plan::build_with(&f.h2, k.build());
                assert!(plan.calibrate(&f.h2, cal_rounds).is_usable(), "H2 calibration degenerated [{k}]");
                (k, plan)
            })
            .collect();
        let mut arena = Arena::new();

        // pin: every calibrated row's output is bitwise equal to its static
        // row's output (re-balancing only re-partitions the task lists)
        for ((kind, sp), (_, cp)) in h_plans.iter().zip(&h_cal) {
            let (mut ys, mut yc) = (vec![0.0; n], vec![0.0; n]);
            sp.execute(&f.h, 1.0, &x, &mut ys, &mut arena);
            cp.execute(&f.h, 1.0, &x, &mut yc, &mut arena);
            assert_bitwise(&yc, &ys, &format!("H plan [{kind}]"));
        }
        for ((kind, sp), (_, cp)) in uh_plans.iter().zip(&uh_cal) {
            let (mut ys, mut yc) = (vec![0.0; n], vec![0.0; n]);
            sp.execute(&f.uh, 1.0, &x, &mut ys, &mut arena);
            cp.execute(&f.uh, 1.0, &x, &mut yc, &mut arena);
            assert_bitwise(&yc, &ys, &format!("UH plan [{kind}]"));
        }
        for ((kind, sp), (_, cp)) in h2_plans.iter().zip(&h2_cal) {
            let (mut ys, mut yc) = (vec![0.0; n], vec![0.0; n]);
            sp.execute(&f.h2, 1.0, &x, &mut ys, &mut arena);
            cp.execute(&f.h2, 1.0, &x, &mut yc, &mut arena);
            assert_bitwise(&yc, &ys, &format!("H2 plan [{kind}]"));
        }
        doc.push(("calibrated bitwise ok".to_string(), Json::Bool(true)));

        // storage-tier rows: the same H operator compressed, packed to a
        // temp HMPK file and re-attached to the mapping — one row streaming
        // straight off the mapped bytes, one with a roomy decode-once hot
        // cache (repeated serves skip decode entirely). Both pinned bitwise
        // against the in-memory compressed plan before benching.
        {
            let mut hz = f.h.clone();
            hz.compress(&CompressionConfig { codec: Codec::Aflp, eps: 1e-9, valr: true });
            let path = std::env::temp_dir().join(format!("hmatc_fig06_{}_{level}.hmpk", std::process::id()));
            let path = path.to_str().unwrap().to_string();
            hmatc::store::pack_h(&hz, &path).expect("pack H");
            let mstore = hmatc::store::MappedStore::open(&path).expect("open pack");
            let mut hm = hz.clone();
            hmatc::store::attach_h(&mut hm, &mstore).expect("attach pack");
            let zplan = HPlan::build(&hz);
            zplan.set_hot_cache(None);
            let mplan = HPlan::build(&hm);
            mplan.set_hot_cache(None);
            let (mut yz, mut ym) = (vec![0.0; n], vec![0.0; n]);
            zplan.execute(&hz, 1.0, &x, &mut yz, &mut arena);
            mplan.execute(&hm, 1.0, &x, &mut ym, &mut arena);
            assert_bitwise(&ym, &yz, "H plan mmap");
            let r = bench_fn(1, 5, 0.02, || mplan.execute(&hm, 1.0, &x, &mut y, &mut arena));
            push_row(&mut t, &mut doc, "H", "", "plan mmap", hz.byte_size(), r.median);
            mplan.set_hot_cache(Some(HotCache::new(256 << 20)));
            mplan.execute(&hm, 1.0, &x, &mut ym, &mut arena);
            assert_bitwise(&ym, &yz, "H plan mmap hot-cache");
            let r = bench_fn(1, 5, 0.02, || mplan.execute(&hm, 1.0, &x, &mut y, &mut arena));
            push_row(&mut t, &mut doc, "H", "", "plan mmap hot-cache", hz.byte_size(), r.median);
            drop(mplan);
            drop(hm);
            drop(mstore);
            std::fs::remove_file(&path).ok();
        }

        // sharded-coordinator row: the H operator split into 2 row shards
        // (the same ShardPlan slices the scatter/gather tier serves),
        // executed shard by shard and reassembled from the owned rows —
        // pinned bitwise against the unsharded planned operator before
        // benching, so the row measures the partitioning overhead honestly
        {
            let op = PlannedOperator::from_h_with(std::sync::Arc::new(f.h.clone()), ExecutorKind::StaticLpt);
            let shards: Vec<ShardPlan> = row_partition(&op, 2)
                .expect("partition H operator")
                .into_iter()
                .map(|s| ShardPlan::build(&op, s, ExecutorKind::StaticLpt))
                .collect();
            let mut want = vec![0.0; n];
            op.apply(1.0, &x, &mut want);
            let mut got = vec![0.0; n];
            for sp in &shards {
                let rows = sp.owned(false);
                let mut part = vec![0.0; rows.len()];
                sp.apply_owned(false, 1.0, &x, None, &mut part);
                got[rows].copy_from_slice(&part);
            }
            assert_bitwise(&got, &want, "H plan sharded-coord:2");
            doc.push(("sharded-coord bitwise ok".to_string(), Json::Bool(true)));
            let r = bench_fn(1, 5, 0.02, || {
                for sp in &shards {
                    let rows = sp.owned(false);
                    sp.apply_owned(false, 1.0, &x, None, &mut y[rows]);
                }
            });
            push_row(&mut t, &mut doc, "H", "", "plan sharded-coord:2", f.h.byte_size(), r.median);
        }

        for algo in MvmAlgorithm::all() {
            match algo {
                MvmAlgorithm::Stacked => {
                    let r = bench_fn(1, 5, 0.02, || hmatc::mvm::hmvm::stacked_with(&stacked, 1.0, &f.h, &x, &mut y));
                    push_row(&mut t, &mut doc, "H", "", algo.name(), f.h.byte_size(), r.median);
                }
                MvmAlgorithm::Plan => {
                    for (kind, plan) in &h_plans {
                        let r = bench_fn(1, 5, 0.02, || plan.execute(&f.h, 1.0, &x, &mut y, &mut arena));
                        push_row(&mut t, &mut doc, "H", "", &plan_label(*kind), f.h.byte_size(), r.median);
                    }
                    for (kind, plan) in &h_cal {
                        let r = bench_fn(1, 5, 0.02, || plan.execute(&f.h, 1.0, &x, &mut y, &mut arena));
                        push_row(&mut t, &mut doc, "H", "", &cal_label(*kind), f.h.byte_size(), r.median);
                    }
                }
                _ => {
                    let r = bench_fn(1, 5, 0.02, || hmatc::mvm::mvm(1.0, &f.h, &x, &mut y, algo));
                    push_row(&mut t, &mut doc, "H", "", algo.name(), f.h.byte_size(), r.median);
                }
            }
        }
        for algo in UniMvmAlgorithm::all() {
            match algo {
                UniMvmAlgorithm::Plan => {
                    for (kind, plan) in &uh_plans {
                        let r = bench_fn(1, 5, 0.02, || plan.execute(&f.uh, 1.0, &x, &mut y, &mut arena));
                        push_row(&mut t, &mut doc, "UH", "uh ", &plan_label(*kind), f.uh.byte_size(), r.median);
                    }
                    for (kind, plan) in &uh_cal {
                        let r = bench_fn(1, 5, 0.02, || plan.execute(&f.uh, 1.0, &x, &mut y, &mut arena));
                        push_row(&mut t, &mut doc, "UH", "uh ", &cal_label(*kind), f.uh.byte_size(), r.median);
                    }
                }
                _ => {
                    let r = bench_fn(1, 5, 0.02, || hmatc::mvm::uniform_mvm(1.0, &f.uh, &x, &mut y, algo));
                    let name = match algo {
                        UniMvmAlgorithm::Mutex => "mutex",
                        UniMvmAlgorithm::RowWise => "row wise",
                        UniMvmAlgorithm::SepCoupling => "sep coupling",
                        UniMvmAlgorithm::Plan => unreachable!(),
                    };
                    push_row(&mut t, &mut doc, "UH", "uh ", name, f.uh.byte_size(), r.median);
                }
            }
        }
        for algo in H2MvmAlgorithm::all() {
            match algo {
                H2MvmAlgorithm::Plan => {
                    for (kind, plan) in &h2_plans {
                        let r = bench_fn(1, 5, 0.02, || plan.execute(&f.h2, 1.0, &x, &mut y, &mut arena));
                        push_row(&mut t, &mut doc, "H2", "h2 ", &plan_label(*kind), f.h2.byte_size(), r.median);
                    }
                    for (kind, plan) in &h2_cal {
                        let r = bench_fn(1, 5, 0.02, || plan.execute(&f.h2, 1.0, &x, &mut y, &mut arena));
                        push_row(&mut t, &mut doc, "H2", "h2 ", &cal_label(*kind), f.h2.byte_size(), r.median);
                    }
                }
                _ => {
                    let r = bench_fn(1, 5, 0.02, || hmatc::mvm::h2_mvm(1.0, &f.h2, &x, &mut y, algo));
                    let name = match algo {
                        H2MvmAlgorithm::Mutex => "mutex",
                        H2MvmAlgorithm::RowWise => "row wise",
                        H2MvmAlgorithm::Plan => unreachable!(),
                    };
                    push_row(&mut t, &mut doc, "H2", "h2 ", name, f.h2.byte_size(), r.median);
                }
            }
        }
        t.print();
        out.push(Json::obj(doc.iter().map(|(k, v)| (k.as_str(), v.clone())).collect()));
    }

    // vs eps at the largest default size (skipped in --quick)
    let mut eps_out = Vec::new();
    if !quick {
        let p = Problem::new(*levels.last().unwrap());
        for &e in &default_eps() {
            let f = Formats::build(&p, e);
            let n = p.n();
            let mut rng = Rng::new(2);
            let x = rng.vector(n);
            let mut y = vec![0.0; n];
            let h_plan = HPlan::build(&f.h);
            let mut arena = Arena::new();
            let rh = bench_fn(1, 5, 0.02, || hmatc::mvm::mvm(1.0, &f.h, &x, &mut y, MvmAlgorithm::ClusterLists));
            let rp = bench_fn(1, 5, 0.02, || h_plan.execute(&f.h, 1.0, &x, &mut y, &mut arena));
            let ru = bench_fn(1, 5, 0.02, || hmatc::mvm::uniform_mvm(1.0, &f.uh, &x, &mut y, UniMvmAlgorithm::RowWise));
            let r2 = bench_fn(1, 5, 0.02, || hmatc::mvm::h2_mvm(1.0, &f.h2, &x, &mut y, H2MvmAlgorithm::RowWise));
            println!(
                "eps {e:.0e}: H {} | H plan {} | UH {} | H2 {}",
                hmatc::util::fmt_secs(rh.median),
                hmatc::util::fmt_secs(rp.median),
                hmatc::util::fmt_secs(ru.median),
                hmatc::util::fmt_secs(r2.median)
            );
            eps_out.push(Json::obj(vec![
                ("eps", e.into()),
                ("h", rh.median.into()),
                ("h plan", rp.median.into()),
                ("uh", ru.median.into()),
                ("h2", r2.median.into()),
            ]));
        }
    }

    let doc = Json::obj(vec![("vs_n", Json::arr(out)), ("vs_eps", Json::arr(eps_out))]);
    write_result("fig06_mvm_algorithms", &doc);
    write_bench_json("fig06", &doc);
}
