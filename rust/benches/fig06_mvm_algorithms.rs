//! Figure 6 — runtime of the MVM algorithm variants for H (left), UH
//! (center) and H² (right), vs n (eps fixed) and vs eps (n fixed).
//!
//! Expected shape (paper, on a many-core machine): "cluster lists" ≈
//! "stacked" ≈ "chunks" fastest; "thread local" slower (reduction overhead);
//! for UH "row wise" best; for H² "row wise" ≥ "mutex". On this single-core
//! sandbox the ordering degenerates to per-algorithm bookkeeping overhead —
//! the reduction overhead of "thread local" and the lock overhead of
//! mutex/atomic variants remain visible.

use hmatc::bench::workloads::{Formats, Problem};
use hmatc::bench::{bench_fn, default_eps, default_levels, write_result, Table};
use hmatc::mvm::{H2MvmAlgorithm, MvmAlgorithm, UniMvmAlgorithm};
use hmatc::util::args::Args;
use hmatc::util::json::Json;
use hmatc::util::Rng;

fn main() {
    let args = Args::from_env();
    let levels = default_levels(args.flag("large"));
    let eps = 1e-6;
    let mut out = Vec::new();

    for &level in &levels {
        let p = Problem::new(level);
        let f = Formats::build(&p, eps);
        let n = p.n();
        let mut rng = Rng::new(1);
        let x = rng.vector(n);
        let mut y = vec![0.0; n];

        println!("\n== Fig. 6: n = {n}, eps = {eps:.0e} ==");
        let mut t = Table::new(&["format", "algorithm", "median", "GB/s"]);
        let mut doc = vec![("n", Json::from(n))];

        // the stacked layout is precomputed once (like the paper's setup) —
        // `mvm(.., Stacked)` would rebuild it per product
        let stacked = hmatc::mvm::hmvm::StackedH::new(&f.h);
        for algo in MvmAlgorithm::all() {
            let r = if algo == MvmAlgorithm::Stacked {
                bench_fn(1, 5, 0.02, || hmatc::mvm::hmvm::stacked_with(&stacked, 1.0, &f.h, &x, &mut y))
            } else {
                bench_fn(1, 5, 0.02, || hmatc::mvm::mvm(1.0, &f.h, &x, &mut y, algo))
            };
            t.row(vec![
                "H".into(),
                algo.name().into(),
                hmatc::util::fmt_secs(r.median),
                format!("{:.2}", f.h.byte_size() as f64 / r.median / 1e9),
            ]);
            doc.push((algo.name(), r.median.into()));
        }
        for algo in UniMvmAlgorithm::all() {
            let r = bench_fn(1, 5, 0.02, || hmatc::mvm::uniform_mvm(1.0, &f.uh, &x, &mut y, algo));
            t.row(vec![
                "UH".into(),
                algo.name().into(),
                hmatc::util::fmt_secs(r.median),
                format!("{:.2}", f.uh.byte_size() as f64 / r.median / 1e9),
            ]);
            doc.push(match algo {
                UniMvmAlgorithm::Mutex => ("uh mutex", r.median.into()),
                UniMvmAlgorithm::RowWise => ("uh row wise", r.median.into()),
                UniMvmAlgorithm::SepCoupling => ("uh sep coupling", r.median.into()),
            });
        }
        for algo in H2MvmAlgorithm::all() {
            let r = bench_fn(1, 5, 0.02, || hmatc::mvm::h2_mvm(1.0, &f.h2, &x, &mut y, algo));
            t.row(vec![
                "H2".into(),
                algo.name().into(),
                hmatc::util::fmt_secs(r.median),
                format!("{:.2}", f.h2.byte_size() as f64 / r.median / 1e9),
            ]);
            doc.push(match algo {
                H2MvmAlgorithm::Mutex => ("h2 mutex", r.median.into()),
                H2MvmAlgorithm::RowWise => ("h2 row wise", r.median.into()),
            });
        }
        t.print();
        out.push(Json::obj(doc));
    }

    // vs eps at the largest default size
    let p = Problem::new(*levels.last().unwrap());
    let mut eps_out = Vec::new();
    for &e in &default_eps() {
        let f = Formats::build(&p, e);
        let n = p.n();
        let mut rng = Rng::new(2);
        let x = rng.vector(n);
        let mut y = vec![0.0; n];
        let rh = bench_fn(1, 5, 0.02, || hmatc::mvm::mvm(1.0, &f.h, &x, &mut y, MvmAlgorithm::ClusterLists));
        let ru = bench_fn(1, 5, 0.02, || hmatc::mvm::uniform_mvm(1.0, &f.uh, &x, &mut y, UniMvmAlgorithm::RowWise));
        let r2 = bench_fn(1, 5, 0.02, || hmatc::mvm::h2_mvm(1.0, &f.h2, &x, &mut y, H2MvmAlgorithm::RowWise));
        println!(
            "eps {e:.0e}: H {} | UH {} | H2 {}",
            hmatc::util::fmt_secs(rh.median),
            hmatc::util::fmt_secs(ru.median),
            hmatc::util::fmt_secs(r2.median)
        );
        eps_out.push(Json::obj(vec![
            ("eps", e.into()),
            ("h", rh.median.into()),
            ("uh", ru.median.into()),
            ("h2", r2.median.into()),
        ]));
    }

    write_result("fig06_mvm_algorithms", &Json::obj(vec![("vs_n", Json::arr(out)), ("vs_eps", Json::arr(eps_out))]));
}
