//! Ablation — NUMA-aware execution (topology-pinned pools, per-pool cost
//! coefficients, node-local placement):
//!
//! For each format (H / UH / H²) the same batched product runs on the
//! interleaved backends (`lpt`, `steal` — one flat pool, first-touch
//! wherever the scheduler lands) and on `sharded:K` with K = node count
//! (each sub-pool pinned to one node, shard data first-touched locally,
//! per-pool cost coefficients fitted by calibration). Every node-local
//! product is **bitwise-verified** against the `lpt` baseline in-bench —
//! pinning and per-pool packing may only move work, never change a single
//! output bit — and the verification result lands in the JSON rows.
//!
//! On a single-node host (this sandbox) the sweep still runs: discovery
//! falls back to one node, pinning is off, and the rows record that via the
//! stamped `topology` context, so trajectories from NUMA and non-NUMA hosts
//! stay distinguishable. Emits `BENCH_ablation_numa.json` plus the
//! `bench_results/` archive copy. `--quick` shrinks sizes and sampling so
//! CI can smoke-run it.

use hmatc::bench::workloads::{Formats, Problem};
use hmatc::bench::{bench_fn, write_bench_json, write_result, Table};
use hmatc::la::DMatrix;
use hmatc::par::Topology;
use hmatc::plan::{ExecutorKind, HOperator, PlannedOperator};
use hmatc::util::args::Args;
use hmatc::util::json::Json;
use hmatc::util::Rng;
use std::sync::Arc;

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(x.to_bits() == y.to_bits(), "{what}: entry {i}: {x:e} vs {y:e}");
    }
}

fn main() {
    let args = Args::from_env();
    let quick = args.flag("quick");
    let (warm, samples, min_secs) = if quick { (0, 2, 0.002) } else { (1, 5, 0.02) };
    let topo = Topology::get();
    println!("topology: {}", topo.summary());

    let level = if quick { 2 } else { 3 };
    let eps = 1e-6; // the paper's default block accuracy
    let nrhs = 8;
    let rounds = if quick { 1 } else { 4 };
    let p = Problem::new(level);
    let f = Formats::build(&p, eps);
    let n = p.n();
    let mut rng = Rng::new(17);
    let xm = DMatrix::random(n, nrhs, &mut rng);

    // sharded:K with one shard pool per node is the node-local
    // configuration; on a single-node host K=2 still exercises the sharded
    // path (both pools land on node 0, outputs unchanged).
    let k = topo.num_nodes().max(2);
    let backends: Vec<(ExecutorKind, &str)> = vec![
        (ExecutorKind::StaticLpt, "interleaved"),
        (ExecutorKind::WorkStealing, "interleaved"),
        (ExecutorKind::Sharded(k), "node-local"),
    ];

    let h = Arc::new(f.h);
    let uh = Arc::new(f.uh);
    let h2 = Arc::new(f.h2);
    type Builder = Box<dyn Fn(ExecutorKind) -> PlannedOperator>;
    let builders: Vec<(&str, Builder)> = vec![
        ("H", Box::new(move |kind| PlannedOperator::from_h_with(h.clone(), kind))),
        ("UH", Box::new(move |kind| PlannedOperator::from_uniform_with(uh.clone(), kind))),
        ("H2", Box::new(move |kind| PlannedOperator::from_h2_with(h2.clone(), kind))),
    ];

    println!("\n== Ablation: NUMA placement, batched product (n={n}, b={nrhs}) ==");
    let mut t = Table::new(&["format", "executor", "placement", "median", "vs lpt", "pool coeffs"]);
    let mut rows = Vec::new();
    for (fname, build) in &builders {
        let mut lpt_median = None;
        let mut baseline: Option<DMatrix> = None;
        for (kind, placement) in &backends {
            let op = build(*kind);
            // calibration pool-tags timings on sharded backends and fits the
            // per-pool overlay coefficients the packing then uses
            op.calibrate(rounds);
            let mut y = DMatrix::zeros(n, nrhs);
            op.apply_multi(1.0, &xm, &mut y);
            let verified = match &baseline {
                None => {
                    baseline = Some(y.clone());
                    true
                }
                Some(b) => {
                    assert_bits_eq(y.data(), b.data(), &format!("{fname} [{kind}] vs lpt"));
                    true
                }
            };
            let mut ybench = DMatrix::zeros(n, nrhs);
            let r = bench_fn(warm, samples, min_secs, || op.apply_multi(1.0, &xm, &mut ybench));
            let speedup = match lpt_median {
                None => {
                    lpt_median = Some(r.median);
                    1.0
                }
                Some(base) => base / r.median,
            };
            let pools = op.plan_stats().pool_cost_sources;
            let pools_label = if pools.is_empty() { "-".to_string() } else { pools.join(",") };
            t.row(vec![
                (*fname).to_string(),
                op.executor_name(),
                (*placement).to_string(),
                hmatc::util::fmt_secs(r.median),
                format!("{speedup:.2}x"),
                pools_label,
            ]);
            rows.push(Json::obj(vec![
                ("format", (*fname).into()),
                ("executor", op.executor_name().into()),
                ("placement", (*placement).into()),
                ("nrhs", nrhs.into()),
                ("n", n.into()),
                ("median", r.median.into()),
                ("speedup_vs_lpt", speedup.into()),
                ("bitwise_verified", verified.into()),
                ("pool_cost_sources", Json::arr(pools.iter().map(|s| Json::Str(s.to_string())).collect())),
            ]));
        }
    }
    t.print();
    println!("\nall node-local products bitwise-verified against the lpt baseline");

    let doc = Json::obj(vec![
        ("quick", quick.into()),
        ("nodes", topo.num_nodes().into()),
        ("pinned", topo.pin_enabled().into()),
        ("rows", Json::arr(rows)),
    ]);
    write_result("ablation_numa", &doc);
    write_bench_json("ablation_numa", &doc);
}
